"""End-to-end driver: train a (reduced) LM for a few hundred steps on CPU
with the full production stack — sharded train step, stateless data,
async checkpointing, fault-tolerant controller.

    PYTHONPATH=src python examples/train_lm.py [--arch llama3-8b] [--steps 300]

The same launcher scales to the 512-chip mesh by swapping make_host_mesh()
for make_production_mesh() and dropping --smoke.
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv += ["--arch", "llama3-8b"]
    if not any(a.startswith("--steps") for a in argv):
        argv += ["--steps", "300"]
    sys.argv = [sys.argv[0], "--smoke", "--ckpt-dir", "/tmp/repro_train_lm",
                *argv]
    train.main()
