"""Replay the paper's §5 experiments as case discussions.

Prints the comprehensive optimization (constraint cases + chosen plans) for
the paper's four test problems — matrix addition (Fig. 2), matmul
(Fig. 3/4 + Table 1), 1D Jacobi (Fig. 7 + Table 2), matrix transposition
(Fig. 8 + Table 3) — and then reproduces the *shape* of the paper's tables
by sweeping program parameters with the offline performance model.

    PYTHONPATH=src python examples/paper_case_study.py
"""
import numpy as np

from repro.core import (PAPER_M2050, TPU_V5E, case_table, comprehensive_tree,
                        enumerate_candidates, tree_report)
from repro.kernels.jacobi1d import FAMILY as JACOBI
from repro.kernels.matadd import FAMILY as MATADD
from repro.kernels.matmul import FAMILY as MATMUL
from repro.kernels.transpose import FAMILY as TRANSPOSE

for family, datasets in [
    (MATADD, [{"M": 1 << 10, "N": 1 << 10}, {"M": 1 << 13, "N": 1 << 13}]),
    (MATMUL, [{"M": 1 << 10, "N": 1 << 10, "K": 1 << 10},
              {"M": 1 << 11, "N": 1 << 11, "K": 1 << 11}]),   # Table 1 sizes
    (JACOBI, [{"N": (1 << 15) + 2}]),                          # Table 2 size
    (TRANSPOSE, [{"M": 1 << 14, "N": 1 << 14}]),               # Table 3 size
]:
    leaves = comprehensive_tree(family)
    print("=" * 72)
    print(f"{family.name}: {len(leaves)} cases in the comprehensive tree")
    print(tree_report(leaves[:2]))
    print("  ...")
    for data, cand in case_table(family, TPU_V5E, datasets):
        print(f"  input {data} -> {cand.describe()}")

print("=" * 72)
print("Paper Table-1 analogue: best matmul variant shifts with input size")
for n in (1 << 10, 1 << 11):
    cands = enumerate_candidates(MATMUL, TPU_V5E,
                                 {"M": n, "N": n, "K": n})
    cands.sort(key=lambda c: c.score, reverse=True)
    print(f"  n=2^{int(np.log2(n))}: "
          + " | ".join(c.describe() for c in cands[:3]))
