"""Continuous-batching serving demo (reduced config, real engine).

    PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b] [--requests 12]
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv += ["--arch", "yi-6b"]
    sys.argv = [sys.argv[0], *argv]
    serve.main()
