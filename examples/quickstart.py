"""Quickstart: comprehensive optimization of a parametric matmul kernel.

Mirrors the paper end to end in one page:
 1. build the comprehensive decision tree OFFLINE (machine params symbolic),
 2. print the case discussion (paper Fig. 2 analogue),
 3. bind a concrete machine + two input sizes at LOAD time,
 4. instantiate the selected Pallas kernel and validate vs the oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, best_variant, comprehensive_tree, tree_report
from repro.kernels import ref
from repro.kernels.matmul import FAMILY

# 1. offline: the case discussion --------------------------------------------
leaves = comprehensive_tree(FAMILY)
print(f"comprehensive tree for '{FAMILY.name}': {len(leaves)} cases\n")
print("\n".join(tree_report(leaves[:2]).splitlines()[:12]))
print("  ... (remaining cases elided)\n")

# 2. load time: bind machine + data, pick the best variant --------------------
for n in (1024, 4096):
    cand = best_variant(FAMILY, TPU_V5E, {"M": n, "N": n, "K": n})
    print(f"n={n}: selected {cand.describe()}")

# 3. instantiate + validate ----------------------------------------------------
cand = best_variant(FAMILY, TPU_V5E, {"M": 512, "N": 512, "K": 512})
kernel = FAMILY.instantiate(cand.plan, cand.assignment, interpret=True)
a = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.float32)
out = kernel(a, b)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                           rtol=1e-4, atol=1e-3)
print("\nPallas kernel (interpret mode) matches the jnp oracle — OK")
