"""Benchmark harness — one function per paper table/figure + roofline dump.

Wall-clock numbers are CPU-XLA (the container's only runtime) and are used
for *relative* variant comparisons; the TPU-side ranking column comes from
the comprehensive tree's offline performance model, which is the mechanism
the paper evaluates.  CSV columns: name,us_per_call,derived.

    PYTHONPATH=src python -m benchmarks.run [--quick]
    PYTHONPATH=src python -m benchmarks.run --only dispatch,compile \
        --json BENCH_dispatch.json        # machine-readable, CI gate input

``--json`` writes every measured row as ``{"rows": [{name, us, derived}]}``
(plus meta); ``scripts/check_bench.py`` compares that against the committed
``benchmarks/baseline.json`` and fails CI on a >2x cold-dispatch regression.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, best_variant, comprehensive_tree, \
    enumerate_candidates
from repro.kernels import ops, ref
from repro.kernels.jacobi1d import FAMILY as JACOBI
from repro.kernels.matadd import FAMILY as MATADD
from repro.kernels.matmul import FAMILY as MATMUL
from repro.kernels.transpose import FAMILY as TRANSPOSE


def _time(fn, *args, iters=5, warmup=2) -> float:
    """Median wall-time in microseconds (jit path, CPU)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_table1_matmul(quick=False):
    """Paper Table 1: best thread-block format shifts with input size.

    Derived column: the offline-model ranking of (bn,s,bm) per size —
    the framework-level reproduction of the size-dependent optimum."""
    rows = []
    sizes = [1 << 9] if quick else [1 << 10, 1 << 11]
    mm = jax.jit(ref.matmul)
    for n in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
        us = _time(mm, a, b, iters=3 if n > 1024 else 5)
        cands = enumerate_candidates(MATMUL, TPU_V5E,
                                     {"M": n, "N": n, "K": n})
        cands.sort(key=lambda c: c.score, reverse=True)
        top = cands[0]
        derived = (f"best=(bm={top.assignment['bm']} "
                   f"bn={top.assignment['bn']} s={top.assignment['s']} "
                   f"bk={top.assignment['bk']}) score={top.score:.3f} "
                   f"nleaves={len(set(c.leaf_index for c in cands))}")
        rows.append((f"table1_matmul_n{n}", us, derived))
    return rows


def bench_table2_jacobi(quick=False):
    """Paper Table 2: 1D Jacobi, thread-block x granularity sweep."""
    n = (1 << 12) + 2 if quick else (1 << 15) + 2
    steps = 4
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    jac = jax.jit(lambda v: ref.jacobi1d(v, steps))
    us = _time(jac, x)
    cand = best_variant(JACOBI, TPU_V5E, {"N": n, "T": steps})
    return [(f"table2_jacobi_n{n}", us, f"best={cand.describe()}")]


def bench_table3_transpose(quick=False):
    """Paper Table 3: matrix transposition block sweep."""
    n = 1 << 10 if quick else 1 << 13
    a = jax.random.normal(jax.random.PRNGKey(3), (n, n))
    tr = jax.jit(ref.transpose)
    us = _time(tr, a)
    cand = best_variant(TRANSPOSE, TPU_V5E, {"M": n, "N": n})
    return [(f"table3_transpose_n{n}", us, f"best={cand.describe()}")]


def bench_fig2_matadd(quick=False):
    """Paper Fig. 2: the matrix-addition comprehensive kernel (case count)."""
    n = 1 << 10 if quick else 1 << 12
    a = jax.random.normal(jax.random.PRNGKey(4), (n, n))
    add = jax.jit(ref.matadd)
    us = _time(add, a, a)
    leaves = comprehensive_tree(MATADD)
    cand = best_variant(MATADD, TPU_V5E, {"M": n, "N": n})
    return [(f"fig2_matadd_n{n}", us,
             f"cases={len(leaves)} best={cand.describe()}")]


def bench_dispatch_cache(quick=False):
    """Amortized dispatch: cold tree-search vs warm DispatchCache lookup.

    Derived column reports the speedup — the number that justifies shipping
    precompiled artifacts for serving-style traffic where the same
    (family, machine, shape) triple recurs millions of times.  The cold row
    is the compiled symbolic core's headline number (vectorized candidate
    enumeration; was ~6.4s with per-candidate exact Fraction arithmetic)."""
    from repro.artifacts.dispatch import DispatchCache
    from repro.core.select import STATS
    cache = DispatchCache()
    data = {"M": 1024, "N": 1024, "K": 1024}
    STATS.reset()
    t0 = time.perf_counter()
    cold = cache.best_variant(MATMUL, TPU_V5E, data)
    cold_us = (time.perf_counter() - t0) * 1e6
    iters = 200 if quick else 2000
    t0 = time.perf_counter()
    for _ in range(iters):
        warm = cache.best_variant(MATMUL, TPU_V5E, data)
    warm_us = (time.perf_counter() - t0) * 1e6 / iters
    assert warm == cold and STATS.enumerate_calls == 1
    return [
        ("dispatch_cold_matmul", cold_us,
         f"best={cold.describe()} rows={STATS.rows_screened}"),
        ("dispatch_warm_matmul", warm_us,
         f"speedup={cold_us / max(warm_us, 1e-9):.0f}x "
         f"enumerate_calls={STATS.enumerate_calls}"),
    ]


def bench_dispatch_reference(quick=False):
    """The pre-compiled-core exact enumeration, for the speedup column."""
    from repro.core.select import enumerate_candidates
    n = 512 if quick else 1024
    data = {"M": n, "N": n, "K": n}
    t0 = time.perf_counter()
    cands = enumerate_candidates(MATMUL, TPU_V5E, data, use_compiled=False)
    ref_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    enumerate_candidates(MATMUL, TPU_V5E, data, use_compiled=True)
    fast_us = (time.perf_counter() - t0) * 1e6
    return [("dispatch_reference_matmul", ref_us,
             f"cands={len(cands)} compiled={fast_us:.0f}us "
             f"speedup={ref_us / max(fast_us, 1e-9):.0f}x")]


def bench_compile_sweep(quick=False):
    """Offline ``compile_family`` sweep (what scripts/compile_artifacts.py
    pays per family x machine x bucket) — the compiled core's other
    beneficiary."""
    from repro.artifacts import ArtifactStore, compile_family
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        report = compile_family(MATMUL, ArtifactStore(tmp),
                                machines=[TPU_V5E], quick=quick)
        us = (time.perf_counter() - t0) * 1e6
    return [("compile_sweep_matmul", us,
             f"buckets={report['dispatch'][TPU_V5E.name]['buckets']} "
             f"enumerate_calls={report['enumerate_calls']} "
             f"rows={report['rows_screened']}")]


def bench_tuning_sweep(quick=False):
    """The measure -> calibrate -> compact loop (scripts/tune_artifacts.py)
    end to end for one matmul bucket on interpreted Pallas — the cost of
    closing the offline-ranking loop against the machine, and the CI gate
    that keeps the tuning pipeline runnable."""
    from repro.artifacts import ArtifactStore, compile_family
    from repro.tuning import MeasureConfig, calibrate_table, compact_table, \
        measure_table
    n = 128 if quick else 256
    shape = {"M": n, "N": n, "K": n}
    cfg = MeasureConfig(iters=2, warmup=1, trim=0, max_dim=n,
                        top_k=2 if quick else 4)
    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(tmp)
        compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[shape])
        table = store.load_dispatch(MATMUL.name, TPU_V5E.name)
        t0 = time.perf_counter()
        samples = measure_table(MATMUL, table, cfg)
        tuned = compact_table(calibrate_table(MATMUL, table, samples),
                              samples)
        store.save_dispatch(tuned)
        us = (time.perf_counter() - t0) * 1e6
    ok = sum(s.us is not None for s in samples)
    comp = tuned["compaction"]
    return [("tuning_sweep_matmul", us,
             f"measured={ok}/{len(samples)} "
             f"variants={comp['total_variants_measured']}->"
             f"{len(comp['variants'])} "
             f"covered={comp['buckets_covered']}/{comp['buckets_total']}")]


def bench_tree_build():
    """Offline cost of comprehensive optimization itself (paper §6 claims
    the computer-algebra part is not a bottleneck)."""
    from repro.core import comprehensive_optimization
    rows = []
    for fam in (MATMUL, MATADD, JACOBI, TRANSPOSE):
        t0 = time.perf_counter()
        leaves = comprehensive_optimization(fam)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"treebuild_{fam.name}", us, f"leaves={len(leaves)}"))
    return rows


def bench_lm_step(quick=False):
    """End-to-end smoke-scale LM train step wall time."""
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.optim import adamw, constant
    from repro.runtime import build_train_step
    rows = []
    for arch in (["llama3_8b"] if quick else
                 ["llama3_8b", "mamba2_130m", "kimi_k2_1t_a32b"]):
        cfg = get_smoke_config(arch)
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw(constant(1e-3))
        state = opt.init(params)
        step = jax.jit(build_train_step(cfg, opt, microbatches=2))
        B, S = 4, 64
        batch = {"tokens": jnp.zeros((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        zero = jnp.zeros((), jnp.int32)
        us = _time(lambda p, s, b: step(p, s, b, zero),
                   params, state, batch, iters=3)
        toks = B * S / (us / 1e6)
        rows.append((f"train_step_{arch}", us, f"tok/s={toks:.0f}"))
    return rows


# Named groups for --only filtering (comma-separated exact names).
BENCH_GROUPS = (
    ("table1", bench_table1_matmul),
    ("jacobi", bench_table2_jacobi),
    ("transpose", bench_table3_transpose),
    ("matadd", bench_fig2_matadd),
    ("dispatch", bench_dispatch_cache),
    ("dispatch_reference", bench_dispatch_reference),
    ("compile", bench_compile_sweep),
    ("tuning", bench_tuning_sweep),
    ("treebuild", lambda quick: bench_tree_build()),
    ("lm", bench_lm_step),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated group names to run "
                         f"(one of: {', '.join(n for n, _ in BENCH_GROUPS)}); "
                         "implies --skip-roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as machine-readable JSON "
                         "(scripts/check_bench.py gates CI on it)")
    args = ap.parse_args()

    selected = None
    if args.only:
        wanted = [w.strip() for w in args.only.split(",") if w.strip()]
        known = {n for n, _ in BENCH_GROUPS}
        unknown = [w for w in wanted if w not in known]
        if unknown:
            ap.error(f"unknown --only group(s) {unknown}; "
                     f"have {sorted(known)}")
        selected = [(n, f) for n, f in BENCH_GROUPS if n in wanted]
    groups = selected if selected is not None else list(BENCH_GROUPS)

    rows = []
    print("name,us_per_call,derived")
    for _, fn in groups:
        for name, us, derived in fn(args.quick):
            rows.append({"name": name, "us": us, "derived": derived})
            print(f"{name},{us:.1f},{derived}", flush=True)

    if args.json:
        payload = {"meta": {"quick": bool(args.quick),
                            "only": args.only or ""},
                   "rows": rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}", flush=True)

    if not args.skip_roofline and selected is None:
        print("\n# Roofline (from dry-run artifacts; see EXPERIMENTS.md)")
        try:
            from . import roofline
            rows = roofline.full_table()
            ok = [r for r in rows if r.get("status") == "OK"]
            print(f"# cells: {len(rows)} total, {len(ok)} OK")
            for r in ok:
                if r.get("flops_total"):
                    print(f"roofline_{r['arch']}_{r['shape']},"
                          f"{r['compute_term_s']*1e6:.1f},"
                          f"dominant={r['dominant']} "
                          f"frac={r['roofline_fraction']:.3f}")
        except Exception as e:                            # noqa: BLE001
            print(f"# roofline unavailable: {e}")


if __name__ == "__main__":
    main()
