"""Benchmark harness — one function per paper table/figure + roofline dump.

Wall-clock numbers are CPU-XLA (the container's only runtime) and are used
for *relative* variant comparisons; the TPU-side ranking column comes from
the comprehensive tree's offline performance model, which is the mechanism
the paper evaluates.  CSV columns: name,us_per_call,derived.

    PYTHONPATH=src python -m benchmarks.run [--quick]
    PYTHONPATH=src python -m benchmarks.run --only dispatch,compile \
        --json BENCH_dispatch.json        # machine-readable, CI gate input

``--json`` writes every measured row as ``{"rows": [{name, us, derived}]}``
(plus meta); ``scripts/check_bench.py`` compares that against the committed
``benchmarks/baseline.json`` and fails CI on a >2x regression of ANY gated
row (cold/warm dispatch, fast-lane warm ops, serve decode, plan-backed
start, compile and tuning sweeps) — and, under ``--strict``, on any
measured row missing from the baseline.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TPU_V5E, best_variant, comprehensive_tree, \
    enumerate_candidates
from repro.kernels import ops, ref
from repro.kernels.jacobi1d import FAMILY as JACOBI
from repro.kernels.matadd import FAMILY as MATADD
from repro.kernels.matmul import FAMILY as MATMUL
from repro.kernels.transpose import FAMILY as TRANSPOSE


def _time(fn, *args, iters=5, warmup=2) -> float:
    """Median wall-time in microseconds (jit path, CPU)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_table1_matmul(quick=False):
    """Paper Table 1: best thread-block format shifts with input size.

    Derived column: the offline-model ranking of (bn,s,bm) per size —
    the framework-level reproduction of the size-dependent optimum."""
    rows = []
    sizes = [1 << 9] if quick else [1 << 10, 1 << 11]
    mm = jax.jit(ref.matmul)
    for n in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.bfloat16)
        us = _time(mm, a, b, iters=3 if n > 1024 else 5)
        cands = enumerate_candidates(MATMUL, TPU_V5E,
                                     {"M": n, "N": n, "K": n})
        cands.sort(key=lambda c: c.score, reverse=True)
        top = cands[0]
        derived = (f"best=(bm={top.assignment['bm']} "
                   f"bn={top.assignment['bn']} s={top.assignment['s']} "
                   f"bk={top.assignment['bk']}) score={top.score:.3f} "
                   f"nleaves={len(set(c.leaf_index for c in cands))}")
        rows.append((f"table1_matmul_n{n}", us, derived))
    return rows


def bench_table2_jacobi(quick=False):
    """Paper Table 2: 1D Jacobi, thread-block x granularity sweep."""
    n = (1 << 12) + 2 if quick else (1 << 15) + 2
    steps = 4
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    jac = jax.jit(lambda v: ref.jacobi1d(v, steps))
    us = _time(jac, x)
    cand = best_variant(JACOBI, TPU_V5E, {"N": n, "T": steps})
    return [(f"table2_jacobi_n{n}", us, f"best={cand.describe()}")]


def bench_table3_transpose(quick=False):
    """Paper Table 3: matrix transposition block sweep."""
    n = 1 << 10 if quick else 1 << 13
    a = jax.random.normal(jax.random.PRNGKey(3), (n, n))
    tr = jax.jit(ref.transpose)
    us = _time(tr, a)
    cand = best_variant(TRANSPOSE, TPU_V5E, {"M": n, "N": n})
    return [(f"table3_transpose_n{n}", us, f"best={cand.describe()}")]


def bench_fig2_matadd(quick=False):
    """Paper Fig. 2: the matrix-addition comprehensive kernel (case count)."""
    n = 1 << 10 if quick else 1 << 12
    a = jax.random.normal(jax.random.PRNGKey(4), (n, n))
    add = jax.jit(ref.matadd)
    us = _time(add, a, a)
    leaves = comprehensive_tree(MATADD)
    cand = best_variant(MATADD, TPU_V5E, {"M": n, "N": n})
    return [(f"fig2_matadd_n{n}", us,
             f"cases={len(leaves)} best={cand.describe()}")]


def bench_dispatch_cache(quick=False):
    """Amortized dispatch: cold tree-search vs warm DispatchCache lookup.

    Derived column reports the speedup — the number that justifies shipping
    precompiled artifacts for serving-style traffic where the same
    (family, machine, shape) triple recurs millions of times.  The cold row
    is the compiled symbolic core's headline number (vectorized candidate
    enumeration; was ~6.4s with per-candidate exact Fraction arithmetic)."""
    from repro.artifacts.dispatch import DispatchCache
    from repro.core.select import STATS
    cache = DispatchCache()
    data = {"M": 1024, "N": 1024, "K": 1024}
    STATS.reset()
    t0 = time.perf_counter()
    cold = cache.best_variant(MATMUL, TPU_V5E, data)
    cold_us = (time.perf_counter() - t0) * 1e6
    iters = 200 if quick else 2000
    t0 = time.perf_counter()
    for _ in range(iters):
        warm = cache.best_variant(MATMUL, TPU_V5E, data)
    warm_us = (time.perf_counter() - t0) * 1e6 / iters
    assert warm == cold and STATS.enumerate_calls == 1
    return [
        ("dispatch_cold_matmul", cold_us,
         f"best={cold.describe()} rows={STATS.rows_screened}"),
        ("dispatch_warm_matmul", warm_us,
         f"speedup={cold_us / max(warm_us, 1e-9):.0f}x "
         f"enumerate_calls={STATS.enumerate_calls}"),
    ]


def bench_dispatch_reference(quick=False):
    """The pre-compiled-core exact enumeration, for the speedup column."""
    from repro.core.select import enumerate_candidates
    n = 512 if quick else 1024
    data = {"M": n, "N": n, "K": n}
    t0 = time.perf_counter()
    cands = enumerate_candidates(MATMUL, TPU_V5E, data, use_compiled=False)
    ref_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    enumerate_candidates(MATMUL, TPU_V5E, data, use_compiled=True)
    fast_us = (time.perf_counter() - t0) * 1e6
    return [("dispatch_reference_matmul", ref_us,
             f"cands={len(cands)} compiled={fast_us:.0f}us "
             f"speedup={ref_us / max(fast_us, 1e-9):.0f}x")]


def bench_compile_sweep(quick=False):
    """Offline ``compile_family`` sweep (what scripts/compile_artifacts.py
    pays per family x machine x bucket) — the compiled core's other
    beneficiary."""
    from repro.artifacts import ArtifactStore, compile_family
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        report = compile_family(MATMUL, ArtifactStore(tmp),
                                machines=[TPU_V5E], quick=quick)
        us = (time.perf_counter() - t0) * 1e6
    return [("compile_sweep_matmul", us,
             f"buckets={report['dispatch'][TPU_V5E.name]['buckets']} "
             f"enumerate_calls={report['enumerate_calls']} "
             f"rows={report['rows_screened']}")]


#: One serving-representative shape per family for the warm-path benches.
WARM_SHAPES = {
    "matmul": {"M": 1024, "N": 1024, "K": 1024},
    "matadd": {"M": 1024, "N": 1024},
    "jacobi1d": {"N": 4096},
    "transpose": {"M": 1024, "N": 1024},
    "flash_attention": {"SQ": 512, "HD": 64},
    "ssd_scan": {"SQ": 512, "HD": 64, "STATE": 64},
}


def bench_warm_dispatch(quick=False):
    """Steady-state select+instantiate per family — the path serving traffic
    multiplies by tokens x ops x requests.

    The measured row is the ops-layer fast lane exactly as the op wrappers
    run it: lock-free cache read + ``DispatchCache.warm_callable``
    returning the pre-built kernel callable (``DispatchCache.freeze`` +
    the instantiation cache).  The derived column reports the
    pre-fast-lane warm path for the speedup, again at the ops layer:
    resolution through the LRU tier — sorted ``DispatchKey`` rebuild under
    the cache lock — plus a fresh ``instantiate`` partial rebuild per
    call, exactly the per-call costs the fast lane removes (ISSUE 4; the
    old path additionally took a per-call default-cache lock, which
    ``get_default_cache`` no longer does, so the comparison is if anything
    conservative)."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.kernels.ops import FAMILIES
    prior = get_default_cache()
    fast_cache = DispatchCache()
    fast_cache.freeze([(FAMILIES[f], TPU_V5E, d)
                       for f, d in WARM_SHAPES.items()])
    legacy_cache = DispatchCache()    # unfrozen: pre-fast-lane resolution
    iters = 2000 if quick else 20000
    rows = []
    try:
        for fname, data in WARM_SHAPES.items():
            fam = FAMILIES[fname]
            # both loops exclude the per-call data-structure build (items
            # tuple here, data dict on the legacy path — ops wrappers build
            # either as a literal from shapes, at near-identical cost):
            # what's timed is resolution, not operand packaging
            items = tuple(data.items())
            set_default_cache(fast_cache)
            fast_us = float("inf")
            for _ in range(3):                 # best-of-3: both loops are
                t0 = time.perf_counter()       # pure host work, min is right
                for _ in range(iters):
                    fn = get_default_cache().warm_callable(fam, TPU_V5E,
                                                           items, False)
                fast_us = min(fast_us,
                              (time.perf_counter() - t0) * 1e6 / iters)
            set_default_cache(legacy_cache)
            legacy_cache.best_variant(fam, TPU_V5E, data)    # warm the LRU
            legacy_iters = max(1, iters // 10)
            legacy_us = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                for _ in range(legacy_iters):
                    # pre-fast-lane select(): locked LRU resolve with
                    # per-call sorted-key rebuild
                    cand = get_default_cache().best_variant(fam, TPU_V5E,
                                                            data)
                    legacy_fn = fam.instantiate_fresh(cand.plan,
                                                      cand.assignment, False)
                legacy_us = min(legacy_us, (time.perf_counter() - t0)
                                * 1e6 / legacy_iters)
            assert fn is not None and legacy_fn is not None
            rows.append((f"warm_dispatch_{fname}", fast_us,
                         f"legacy={legacy_us:.2f}us "
                         f"speedup={legacy_us / max(fast_us, 1e-9):.1f}x "
                         f"ns_per_op={fast_us * 1e3:.0f}"))
    finally:
        set_default_cache(prior)
    return rows


def bench_serve_decode(quick=False):
    """Tokens/s through ``ServeEngine.run_until_drained`` on the dry-run
    (smoke) model with warm+frozen kernel dispatch — the end-to-end number
    the warm-path fast lane exists to protect.  Row value is host-side
    microseconds per generated token (CPU-XLA; relative signal)."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.runtime import ServeEngine
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    # warm_kernels freezes into the process-default cache: run against a
    # private one so later bench groups see an unmutated default
    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          warm_kernels=True)
        rng = np.random.default_rng(0)
        # warmup tick set: compile prefill/decode outside the timed region.
        # A 31-token prompt prefills in chunks 16+8+4+2+1 — every quantized
        # chunk shape the timed prompts (4..23 tokens) can hit.
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)
        eng.run_until_drained()
        nreq, max_new = (3, 8) if quick else (8, 16)
        for _ in range(nreq):
            plen = int(rng.integers(4, 24))
            eng.submit(rng.integers(0, cfg.vocab, plen), max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run_until_drained()
        dt = time.perf_counter() - t0
    finally:
        set_default_cache(prior)
    toks = sum(len(r.out) for r in done)
    assert len(done) == nreq and toks > 0
    return [("serve_decode_smoke", dt * 1e6 / toks,
             f"tok/s={toks / dt:.0f} requests={nreq} "
             f"frozen={len(eng.kernel_plan)}picks")]


def _serve_load_scenario(arch, row, *, quick, nreq, arrival_scale=2.0,
                         plen_fn=None, max_new_hi=None, shared_len=0,
                         prefix_sharing=True, async_depth=2):
    """One load-bench traffic scenario: Poisson arrivals (inter-arrival
    gaps ~ Exp(``arrival_scale``) ticks; 0 = burst, everything at tick 0)
    of mixed-length requests against the ``arch`` smoke config, reported
    as three ``{row}_{tok,p50,p99}_us`` rows.  ``plen_fn(rng)`` draws one
    prompt length (default: the 70% short / 30% long production mix);
    ``shared_len > 0`` prepends a common system-prompt prefix of that many
    tokens to every request — the prefix-sharing fast path (auto-disabled
    engine-side for SSM-bearing archs).  Per-token latency charges each
    generated token its engine tick's wall time — the inter-token gap a
    client of that request observes.  Pool invariants (incl. block-table /
    free-list disjointness) are asserted every tick."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.runtime import ServeEngine
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          page_size=16, prefill_chunk=16,
                          prefix_sharing=prefix_sharing,
                          async_depth=async_depth, warm_kernels=True)
        rng = np.random.default_rng(0)
        # warmup: a 31-token prompt prefills in chunks 16+8+4+2+1 —
        # every quantized chunk shape the timed run can hit — plus decode
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)
        eng.run_until_drained()
        shared = rng.integers(0, cfg.vocab, shared_len)
        if plen_fn is None:
            def plen_fn(r):                  # 70% short / 30% long mix
                return (int(r.integers(4, 13)) if r.random() < 0.7
                        else int(r.integers(24, 57)))
        gaps = (rng.exponential(scale=arrival_scale, size=nreq)
                if arrival_scale > 0 else np.zeros(nreq))
        arrive = np.floor(np.cumsum(gaps)).astype(int)
        plens = [plen_fn(rng) for _ in range(nreq)]
        news = [int(rng.integers(4, max_new_hi or (9 if quick else 17)))
                for _ in range(nreq)]
        per_token, done, submitted, tick = [], [], 0, 0
        t_start = time.perf_counter()
        while len(done) < nreq and tick < 10_000:
            while submitted < nreq and arrive[submitted] <= tick:
                tail = rng.integers(0, cfg.vocab, plens[submitted])
                eng.submit(np.concatenate([shared, tail]),
                           max_new=news[submitted])
                submitted += 1
            before = sum(len(s.req.out) for s in eng.sched.running())
            t0 = time.perf_counter()
            finished = eng.step()
            dt = (time.perf_counter() - t0) * 1e6
            after = sum(len(s.req.out) for s in eng.sched.running()) \
                + sum(len(r.out) for r in finished)
            per_token.extend([dt] * max(0, after - before))
            done.extend(finished)
            eng.pool.check_invariants(
                [s.blocks for s in eng.sched.running()])
            tick += 1
        total_s = time.perf_counter() - t_start
    finally:
        set_default_cache(prior)
    toks = sum(len(r.out) for r in done)
    assert len(done) == nreq and toks > 0 and per_token
    st, pst = eng.sched.stats, eng.pool.stats
    lat = np.asarray(per_token)
    meta = (f"tok/s={toks / total_s:.0f} requests={nreq} ticks={tick} "
            f"chunks={st.prefill_chunks} preempt={st.preemptions} "
            f"waits={st.admission_waits} "
            f"prefix_saved={pst.prefix_tokens_saved} "
            f"cow={pst.cow_copies}")
    return [
        (f"{row}_tok_us", total_s * 1e6 / toks, meta),
        (f"{row}_p50_us", float(np.percentile(lat, 50)), f"tokens={toks}"),
        (f"{row}_p99_us", float(np.percentile(lat, 99)), f"tokens={toks}"),
    ]


def bench_serve_load(quick=False):
    """Poisson-arrival load over the paged engine across the config zoo:
    requests arrive mid-flight with mixed prompt/output lengths, exercising
    chunked prefill interleaved with decode, refcounted block-pool churn,
    prefix sharing, async tick overlap, and admission head-room — the
    production-traffic shapes the scheduler exists for.

    Scenarios (each contributes ``*_tok_us``/``*_p50_us``/``*_p99_us``
    rows, all gated in ``benchmarks/baseline.json``):

    - ``serve_load`` — the llama3 70/30 short/long mix (the PR 6 rows),
      now with prefix sharing + ``async_depth=2`` enabled and a 16-token
      shared system prefix on every prompt; the acceptance gate that the
      new machinery does not regress the existing mix.
    - ``serve_load_mamba`` — the same mix on ``mamba2_130m``: prefix
      sharing auto-disables (recurrent state cannot skip prompt tokens),
      so this gates the async-overlap path on the SSM decode step.
    - ``serve_load_moe`` — the mix on the ``llama4_scout_17b_a16e`` smoke
      scale: routed-expert prefill/decode under paged serving.
    - ``serve_load_burst`` — every request arrives at tick 0 (admission
      pressure, head-room waits, same-tick admissions that cannot share).
    - ``serve_load_flood`` — long-context flood: every prompt is 48–89
      tokens against ``max_len=128``, maximal chunked-prefill pressure and
      pool churn.
    """
    quick_n, full_n = (3, 5), (5, 12)
    n_small = quick_n[0] if quick else full_n[0]
    n_mix = quick_n[1] if quick else full_n[1]
    rows = []
    rows += _serve_load_scenario("llama3_8b", "serve_load", quick=quick,
                                 nreq=n_mix, shared_len=16)
    rows += _serve_load_scenario("mamba2_130m", "serve_load_mamba",
                                 quick=quick, nreq=n_small, shared_len=16)
    rows += _serve_load_scenario("llama4_scout_17b_a16e", "serve_load_moe",
                                 quick=quick, nreq=n_small, shared_len=16)
    rows += _serve_load_scenario("llama3_8b", "serve_load_burst",
                                 quick=quick, nreq=n_mix, arrival_scale=0,
                                 shared_len=16)
    rows += _serve_load_scenario(
        "llama3_8b", "serve_load_flood", quick=quick, nreq=n_small,
        arrival_scale=1.0, max_new_hi=9,
        plen_fn=lambda r: int(r.integers(48, 90)))
    return rows


def bench_serve_prefix_hit(quick=False):
    """Prefix-sharing payoff: N requests sharing an 80% prompt prefix vs
    the same N with disjoint prompts, on the llama3 smoke config with
    ``prefix_sharing=True`` and ``async_depth=2``.

    A leader request carrying the shared prefix drains first (its blocks
    stay resident in the pool's prefix index after retirement), then the N
    followers are submitted together.  Gated rows (``--strict`` in CI):

    - ``serve_prefix_prefill_tok`` — prompt tokens actually computed for
      the N shared-prefix followers (the number prefix sharing shrinks;
      the run **asserts ≥ 2x reduction** vs the disjoint control).
    - ``serve_prefix_p50_us`` / ``serve_prefix_p99_us`` — per-token
      latency of the shared-prefix run (each token charged its tick's
      wall time), so CoW copies and index upkeep cannot silently eat the
      tokens they save.
    """
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.runtime import ServeEngine
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    nreq = 4 if quick else 8
    plen, shared_frac = 40, 0.8
    shared_n = int(plen * shared_frac)

    def drive(shared):
        rng = np.random.default_rng(0)
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          page_size=16, prefill_chunk=16,
                          prefix_sharing=True, async_depth=2,
                          warm_kernels=True)
        # warmup compiles every chunk shape; drop whatever it cached so
        # both runs start from an identical (empty) prefix index
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)
        eng.run_until_drained()
        eng.pool.release_prefix_cache()
        prefix = rng.integers(0, cfg.vocab, shared_n)
        eng.submit(np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab,
                                                plen - shared_n)]),
                   max_new=4)
        eng.run_until_drained()              # leader: populates the index
        st0 = eng.sched.stats.prefill_tokens
        for _ in range(nreq):
            head = (prefix if shared
                    else rng.integers(0, cfg.vocab, shared_n))
            eng.submit(np.concatenate(
                [head, rng.integers(0, cfg.vocab, plen - shared_n)]),
                max_new=8)
        per_token, done, tick = [], [], 0
        while len(done) < nreq and tick < 10_000:
            before = sum(len(s.req.out) for s in eng.sched.running())
            t0 = time.perf_counter()
            finished = eng.step()
            dt = (time.perf_counter() - t0) * 1e6
            after = sum(len(s.req.out) for s in eng.sched.running()) \
                + sum(len(r.out) for r in finished)
            per_token.extend([dt] * max(0, after - before))
            done.extend(finished)
            eng.pool.check_invariants(
                [s.blocks for s in eng.sched.running()])
            tick += 1
        assert len(done) == nreq
        return (eng.sched.stats.prefill_tokens - st0,
                np.asarray(per_token), eng.pool.stats)

    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        disjoint_toks, _, _ = drive(shared=False)
        shared_toks, lat, pst = drive(shared=True)
    finally:
        set_default_cache(prior)
    reduction = disjoint_toks / max(shared_toks, 1)
    assert reduction >= 2.0, (
        f"prefix sharing saved too little prefill: {shared_toks} tokens "
        f"computed vs {disjoint_toks} disjoint ({reduction:.2f}x < 2x)")
    meta = (f"disjoint={disjoint_toks}tok reduction={reduction:.1f}x "
            f"hits={pst.prefix_hits} saved={pst.prefix_tokens_saved} "
            f"cow={pst.cow_copies}")
    return [
        ("serve_prefix_prefill_tok", float(shared_toks), meta),
        ("serve_prefix_p50_us", float(np.percentile(lat, 50)),
         f"requests={nreq}"),
        ("serve_prefix_p99_us", float(np.percentile(lat, 99)),
         f"requests={nreq}"),
    ]


def bench_plan_load(quick=False):
    """Plan-backed serving start (load a shipped serve-plan artifact +
    ``DispatchCache.freeze_resolved``) vs the online traced warm-up it
    replaces — the number that justifies building plans offline and
    shipping them to every host of a serving mesh.  The measured row is the
    plan path; the derived column reports the online path and asserts the
    plan-backed start performed ZERO cold resolutions with picks identical
    to the online freeze (the acceptance properties of ISSUE 5)."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.plans import PlanStore, build_serve_plan, warm_from_plan
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("llama3_8b")
    prior = get_default_cache()
    try:
        with tempfile.TemporaryDirectory() as tmp:
            store = PlanStore(tmp)
            plan, _ = build_serve_plan(cfg, max_len=128,
                                       cache=DispatchCache())
            store.save_plan(plan)
            # online traced warm-up on a fresh cache (trees stay memoized
            # process-wide, so this is the in-process re-warm cost, not the
            # fresh-process cold number gated by dispatch_cold_matmul)
            online_cache = DispatchCache()
            set_default_cache(online_cache)
            t0 = time.perf_counter()
            online_picks = warm_kernel_dispatch(cfg, max_len=128,
                                                plan_store=False)
            online_us = (time.perf_counter() - t0) * 1e6
            # plan-backed start on another fresh cache
            plan_cache = DispatchCache()
            t0 = time.perf_counter()
            picks = warm_from_plan(cfg, max_len=128, store=store,
                                   cache=plan_cache)
            plan_us = (time.perf_counter() - t0) * 1e6
    finally:
        set_default_cache(prior)
    assert picks is not None and plan_cache.stats.cold_builds == 0
    assert {k: v["candidate"] for k, v in picks.items()} == \
           {k: v["candidate"] for k, v in online_picks.items()}
    return [("plan_load_smoke", plan_us,
             f"online={online_us:.0f}us "
             f"speedup={online_us / max(plan_us, 1e-9):.0f}x "
             f"entries={len(picks)} cold=0")]


def bench_tuning_sweep(quick=False):
    """The measure -> calibrate -> compact loop (scripts/tune_artifacts.py)
    end to end for one matmul bucket on interpreted Pallas — the cost of
    closing the offline-ranking loop against the machine, and the CI gate
    that keeps the tuning pipeline runnable."""
    from repro.artifacts import ArtifactStore, compile_family
    from repro.tuning import MeasureConfig, calibrate_table, compact_table, \
        measure_table
    n = 128 if quick else 256
    shape = {"M": n, "N": n, "K": n}
    cfg = MeasureConfig(iters=2, warmup=1, trim=0, max_dim=n,
                        top_k=2 if quick else 4)
    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(tmp)
        compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[shape])
        table = store.load_dispatch(MATMUL.name, TPU_V5E.name)
        t0 = time.perf_counter()
        samples = measure_table(MATMUL, table, cfg)
        tuned = compact_table(calibrate_table(MATMUL, table, samples),
                              samples)
        store.save_dispatch(tuned)
        us = (time.perf_counter() - t0) * 1e6
    ok = sum(s.us is not None for s in samples)
    comp = tuned["compaction"]
    return [("tuning_sweep_matmul", us,
             f"measured={ok}/{len(samples)} "
             f"variants={comp['total_variants_measured']}->"
             f"{len(comp['variants'])} "
             f"covered={comp['buckets_covered']}/{comp['buckets_total']}")]


def bench_tree_build():
    """Offline cost of comprehensive optimization itself (paper §6 claims
    the computer-algebra part is not a bottleneck)."""
    from repro.core import comprehensive_optimization
    rows = []
    for fam in (MATMUL, MATADD, JACOBI, TRANSPOSE):
        t0 = time.perf_counter()
        leaves = comprehensive_optimization(fam)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"treebuild_{fam.name}", us, f"leaves={len(leaves)}"))
    return rows


def bench_lm_step(quick=False):
    """End-to-end smoke-scale LM train step wall time."""
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.optim import adamw, constant
    from repro.runtime import build_train_step
    rows = []
    for arch in (["llama3_8b"] if quick else
                 ["llama3_8b", "mamba2_130m", "kimi_k2_1t_a32b"]):
        cfg = get_smoke_config(arch)
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw(constant(1e-3))
        state = opt.init(params)
        step = jax.jit(build_train_step(cfg, opt, microbatches=2))
        B, S = 4, 64
        batch = {"tokens": jnp.zeros((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        zero = jnp.zeros((), jnp.int32)
        us = _time(lambda p, s, b: step(p, s, b, zero),
                   params, state, batch, iters=3)
        toks = B * S / (us / 1e6)
        rows.append((f"train_step_{arch}", us, f"tok/s={toks:.0f}"))
    return rows


def bench_adaptive_swap(quick=False):
    """Adaptive-serving loop (ISSUE 8): how fast the monitor detects and
    corrects a wrong frozen pick, and what serving costs after the swap.

    ``adaptive_detect_ticks`` is the detection latency in engine ticks for
    a fabricated drift scenario driven by a deterministic skewed timer
    (window x patience probes at probe_every=1 — the architectural bound,
    so a regression means the decision loop itself got lazier, not noise).
    ``adaptive_post_swap_tok_us`` is host µs per generated token through a
    monitored ``ServeEngine`` whose swap fires during warmup traffic —
    the monitored steady state, directly comparable to
    ``serve_decode_smoke``."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.core.select import rank_candidates
    from repro.kernels.ops import FAMILIES
    from repro.models import init_model
    from repro.plans.trace import trace_warm_set
    from repro.runtime import KernelMonitor, ServeEngine
    from repro.runtime.monitor import cand_key

    def skewed_timer(skews, default=4e-3):
        def timer(family, plan, assignment, data, cfg):
            key = tuple(sorted((k, int(v)) for k, v in assignment.items()))
            for (_, asg), secs in skews.items():
                if asg == key:
                    return [secs]
            return [default]
        return timer

    rows = []
    fam = FAMILIES["matmul"]
    data = {"M": 256, "N": 256, "K": 256}

    # -- detection latency: ticks from drift onset to hot-swap ---------------
    cache = DispatchCache()
    ranked = rank_candidates(fam, TPU_V5E, data)
    wrong, best = ranked[1], ranked[0]
    cache.freeze_resolved([(fam, TPU_V5E, data, wrong, "symbolic")])
    mon = KernelMonitor(cache, machine=TPU_V5E, window=4, patience=2,
                        probe_every=1, top_k=2, seed=0,
                        timer=skewed_timer({cand_key(wrong): 8e-3,
                                            cand_key(best): 1e-3}))
    mon.track(fam, data)
    detect = None
    for t in range(16 * mon.window * mon.patience):
        mon.on_tick(t)
        if mon.stats.swaps:
            detect = t + 1
            break
    assert detect is not None and mon.stats.swaps == 1
    rows.append(("adaptive_detect_ticks", float(detect),
                 f"window={mon.window} patience={mon.patience} "
                 f"probes={mon.stats.probes}"))

    # -- post-swap serving cost ----------------------------------------------
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          page_size=16, warm_kernels=True, plan_store=False)
        live = get_default_cache()
        # narrow the monitor to one matmul triple whose frozen pick the
        # timer calls slow: the swap fires on the first warmup tick
        op = next(o for o in trace_warm_set(cfg, max_len=128, page_size=16)
                  if o.family == "matmul")
        ent = live.frozen_entry("matmul", TPU_V5E.name, op.data_dict())
        eng.monitor = KernelMonitor(
            live, machine=TPU_V5E, window=1, patience=1, probe_every=1,
            top_k=2, seed=0,
            timer=skewed_timer({cand_key(ent.candidate): 8e-3}))
        eng.monitor.track(FAMILIES["matmul"], op.data_dict())
        rng = np.random.default_rng(0)
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)   # warmup
        eng.run_until_drained()
        assert eng.monitor.stats.swaps >= 1        # swap landed pre-timing
        nreq, max_new = (3, 8) if quick else (8, 16)
        for _ in range(nreq):
            plen = int(rng.integers(4, 24))
            eng.submit(rng.integers(0, cfg.vocab, plen), max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run_until_drained()
        dt = time.perf_counter() - t0
    finally:
        set_default_cache(prior)
    toks = sum(len(r.out) for r in done)
    assert len(done) == nreq and toks > 0
    rows.append(("adaptive_post_swap_tok_us", dt * 1e6 / toks,
                 f"tok/s={toks / dt:.0f} swaps={eng.monitor.stats.swaps} "
                 f"{eng.monitor.stats_line()}"))
    return rows


def bench_chaos(quick=False):
    """Serving cost under sustained recoverable faults (ISSUE 9): one
    injected ``serve.decode`` kernel failure per ~8-tick window against a
    warm+frozen engine with graceful degradation on.  Every fault demotes
    the pick down the candidate ranking (or retries a non-frozen call), so
    the row prices the demote-and-retry machinery itself — directly
    comparable to ``serve_decode_smoke``, whose fault-free path it
    shadows.  All requests must still finish, with >= 1 DegradeEvent
    recorded."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.runtime import ServeEngine, faults
    from repro.runtime.faults import FaultSpec
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          warm_kernels=True, degrade=True)
        rng = np.random.default_rng(0)
        # warmup tick set (compile outside the timed region), fault-free
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)
        eng.run_until_drained()
        nreq, max_new = (3, 8) if quick else (8, 16)
        for _ in range(nreq):
            plen = int(rng.integers(4, 24))
            eng.submit(rng.integers(0, cfg.vocab, plen), max_new=max_new)
        # the engine's tick cursor (sched.ticks) kept counting through
        # warmup: schedule one decode failure in every 8-tick window the
        # timed run can possibly reach
        start = eng.sched.ticks
        sched = [FaultSpec("serve.decode", t, "error")
                 for t in range(start + 8, start + 400, 8)]
        t0 = time.perf_counter()
        with faults.inject(sched) as inj:
            done = eng.run_until_drained()
        dt = time.perf_counter() - t0
    finally:
        set_default_cache(prior)
    toks = sum(len(r.out) for r in done)
    assert len(done) == nreq and toks > 0
    assert len(inj.fired) >= 1                 # the drill really fired
    assert len(eng.degrade_events) >= 1        # and demoted down the ranking
    return [("serve_degraded_tok_us", dt * 1e6 / toks,
             f"tok/s={toks / dt:.0f} faults={len(inj.fired)} "
             f"demotions={eng._cache.stats.demotions} "
             f"{eng.robustness_line()}")]


def bench_obs_overhead(quick=False):
    """Cost of the flight recorder (ISSUE 10) on the serve fast path: the
    ``serve_decode_smoke`` workload run with tracing off vs tracing ON
    (full event stream + 1-in-8 warm-lane sampling) against one warm
    engine, alternating batches of identical prompts, best-of-N each.
    Row value is the percent regression of us/token with tracing on —
    the baseline pins 5.0 so the standard 2x CI gate enforces the
    tentpole's < 10% overhead contract.  Tracing *off* must stay the
    PR 4 contract: the warm lane costs one module-global load + None
    test, nothing counted."""
    from repro.artifacts.dispatch import (DispatchCache, get_default_cache,
                                          set_default_cache)
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.obs import tracing
    from repro.runtime import ServeEngine
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prior = get_default_cache()
    set_default_cache(DispatchCache())
    try:
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          warm_kernels=True)
        rng = np.random.default_rng(0)
        # warmup tick set: compile every quantized chunk shape outside the
        # timed region (see bench_serve_decode)
        eng.submit(rng.integers(0, cfg.vocab, 31), max_new=2)
        eng.run_until_drained()
        nreq, max_new = (3, 8) if quick else (8, 16)
        prompts = [rng.integers(0, cfg.vocab,
                                int(rng.integers(4, 24))) for _ in range(nreq)]

        def run_batch():
            for p in prompts:
                eng.submit(p, max_new=max_new)
            t0 = time.perf_counter()
            done = eng.run_until_drained()
            dt = time.perf_counter() - t0
            toks = sum(len(r.out) for r in done)
            assert len(done) == nreq and toks > 0
            return dt * 1e6 / toks

        reps, events = 2 if quick else 3, 0
        off_us, on_us = [], []
        for _ in range(reps):                # interleave to cancel drift
            off_us.append(run_batch())
            with tracing(capacity=1 << 16, sample_frozen_every=8) as rec:
                on_us.append(run_batch())
            events += rec.emitted
    finally:
        set_default_cache(prior)
    off, on = min(off_us), min(on_us)
    pct = max(0.1, (on - off) / off * 100.0)
    return [("obs_overhead_pct", pct,
             f"off={off:.1f}us/tok on={on:.1f}us/tok "
             f"events={events} reps={reps}")]


# Named groups for --only filtering (comma-separated exact names).
BENCH_GROUPS = (
    ("table1", bench_table1_matmul),
    ("jacobi", bench_table2_jacobi),
    ("transpose", bench_table3_transpose),
    ("matadd", bench_fig2_matadd),
    ("dispatch", bench_dispatch_cache),
    ("dispatch_reference", bench_dispatch_reference),
    ("warm", bench_warm_dispatch),
    ("serve", bench_serve_decode),
    ("load", bench_serve_load),
    ("prefix", bench_serve_prefix_hit),
    ("plan", bench_plan_load),
    ("compile", bench_compile_sweep),
    ("tuning", bench_tuning_sweep),
    ("treebuild", lambda quick: bench_tree_build()),
    ("lm", bench_lm_step),
    ("adaptive", bench_adaptive_swap),
    ("chaos", bench_chaos),
    ("obs", bench_obs_overhead),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated group names to run "
                         f"(one of: {', '.join(n for n, _ in BENCH_GROUPS)}); "
                         "implies --skip-roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as machine-readable JSON "
                         "(scripts/check_bench.py gates CI on it)")
    args = ap.parse_args()

    selected = None
    if args.only:
        wanted = [w.strip() for w in args.only.split(",") if w.strip()]
        known = {n for n, _ in BENCH_GROUPS}
        unknown = [w for w in wanted if w not in known]
        if unknown:
            ap.error(f"unknown --only group(s) {unknown}; "
                     f"have {sorted(known)}")
        selected = [(n, f) for n, f in BENCH_GROUPS if n in wanted]
    groups = selected if selected is not None else list(BENCH_GROUPS)

    rows = []
    print("name,us_per_call,derived")
    for _, fn in groups:
        for name, us, derived in fn(args.quick):
            rows.append({"name": name, "us": us, "derived": derived})
            print(f"{name},{us:.1f},{derived}", flush=True)

    if args.json:
        payload = {"meta": {"quick": bool(args.quick),
                            "only": args.only or ""},
                   "rows": rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}", flush=True)

    if not args.skip_roofline and selected is None:
        print("\n# Roofline (from dry-run artifacts; see EXPERIMENTS.md)")
        try:
            from . import roofline
            rows = roofline.full_table()
            ok = [r for r in rows if r.get("status") == "OK"]
            print(f"# cells: {len(rows)} total, {len(ok)} OK")
            for r in ok:
                if r.get("flops_total"):
                    print(f"roofline_{r['arch']}_{r['shape']},"
                          f"{r['compute_term_s']*1e6:.1f},"
                          f"dominant={r['dominant']} "
                          f"frac={r['roofline_fraction']:.3f}")
        except Exception as e:                            # noqa: BLE001
            print(f"# roofline unavailable: {e}")


if __name__ == "__main__":
    main()
