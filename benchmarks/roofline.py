"""Roofline assembly from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and reconstructs, per (arch × shape) cell on
the single-pod mesh:

    flops_total  = fixed + L * per_layer         (probe finite difference)
    bytes_total  = same reconstruction on 'bytes accessed'
    coll_bytes   = loop-weighted collective bytes of the full lowering

    compute_term    = flops_total / 197e12            [s, per chip]
    memory_term     = bytes_total / 819e9             [s, per chip]
    collective_term = coll_bytes  / 50e9              [s, per chip, 1 link]

cost_analysis counts a while body once, so the probes lower the model with
layers and inner loops UNROLLED at L=2 and L=4; per-layer cost is the
finite difference and the fixed part (embedding, unembed, loss, optimizer)
falls out (DESIGN.md §8).  MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/
decode), with N = active params for MoE; the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat and dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from repro.configs import get_config
from repro.models.config import SHAPES_BY_NAME

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(HERE, "..", "experiments", "dryrun")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

ARCHS = ["hymba-1.5b", "yi-6b", "llama3-8b", "qwen1.5-4b", "granite-3-8b",
         "whisper-large-v3", "kimi-k2-1t-a32b", "llama4-scout-17b-a16e",
         "chameleon-34b", "mamba2-130m"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(tag: str) -> Optional[Dict]:
    p = os.path.join(DRYRUN, tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def model_flops_per_device(arch: str, shape_name: str) -> float:
    """Useful model FLOPs per step per chip (single-pod, 256 chips)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence per step
        total = 2.0 * n * shape.global_batch
    return total / CHIPS


def cell_roofline(arch: str, shape: str) -> Optional[Dict]:
    full = _load(f"{arch}_{shape}_16x16")
    if full is None:
        return None
    if full.get("status") == "SKIP":
        return {"arch": arch, "shape": shape, "status": "SKIP",
                "reason": full.get("skip_reason", "")}
    if full.get("status") != "OK":
        return {"arch": arch, "shape": shape, "status": "FAIL",
                "reason": full.get("error", "")[:200]}
    p2 = _load(f"{arch}_{shape}_16x16_probe2")
    p4 = _load(f"{arch}_{shape}_16x16_probe4")
    cfg = get_config(arch)
    L = cfg.layers

    rec = {"arch": arch, "shape": shape, "status": "OK",
           "devices": full["devices"],
           "microbatches": full.get("microbatches"),
           "peak_bytes": full["memory"]["peak_bytes"],
           "arg_bytes": full["memory"]["argument_bytes"],
           "temp_bytes": full["memory"]["temp_bytes"]}

    if p2 and p4 and p2.get("status") == "OK" and p4.get("status") == "OK":
        def recon(key):
            a, b = p2["cost"][key], p4["cost"][key]
            if a is None or b is None:
                return None
            per_layer = (b - a) / 2.0
            fixed = a - 2.0 * per_layer
            return max(0.0, fixed + L * per_layer), per_layer, fixed
        fl = recon("flops")
        by = recon("bytes_accessed")
        rec["flops_total"], rec["flops_per_layer"], rec["flops_fixed"] = fl
        rec["bytes_total"], rec["bytes_per_layer"], rec["bytes_fixed"] = by
        # collective bytes: probes give per-layer flat; full gives weighted
        c2 = p2["collectives"]["flat_bytes"]
        c4 = p4["collectives"]["flat_bytes"]
        rec["coll_probe_total"] = max(
            0.0, (c2 - 2 * (c4 - c2) / 2) + L * (c4 - c2) / 2)
    else:
        rec["flops_total"] = rec["bytes_total"] = None

    rec["coll_bytes"] = full["collectives"]["weighted_bytes"]
    rec["coll_counts"] = full["collectives"]["weighted_counts"]

    if rec.get("flops_total"):
        rec["compute_term_s"] = rec["flops_total"] / PEAK_FLOPS
        rec["memory_term_s"] = rec["bytes_total"] / HBM_BW
        rec["collective_term_s"] = rec["coll_bytes"] / ICI_BW
        terms = {"compute": rec["compute_term_s"],
                 "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        mf = model_flops_per_device(arch, shape)
        rec["model_flops"] = mf
        rec["useful_ratio"] = mf / rec["flops_total"] if rec["flops_total"] \
            else None
        rec["roofline_fraction"] = (mf / PEAK_FLOPS) / max(terms.values())
        rec["fits_hbm"] = (rec["peak_bytes"] or 0) + (rec["arg_bytes"] or 0) \
            <= 16 * 1024**3
    return rec


def full_table():
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            r = cell_roofline(arch, shape)
            if r is not None:
                out.append(r)
    return out


def fmt_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | fits 16G |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "OK" or not r.get("flops_total"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r.get('reason','')[:60]} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.3g} | "
            f"{r['memory_term_s']:.3g} | {r['collective_term_s']:.3g} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def main():
    rows = full_table()
    os.makedirs(os.path.join(HERE, "..", "experiments"), exist_ok=True)
    with open(os.path.join(HERE, "..", "experiments", "roofline.json"),
              "w") as f:
        json.dump(rows, f, indent=1)
    print(fmt_markdown(rows))


if __name__ == "__main__":
    main()
