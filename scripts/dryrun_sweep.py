#!/usr/bin/env python
"""Run the full dry-run sweep: every (arch x shape) x {16x16, 2x16x16} full
lowering plus the L=2/L=4 roofline probes (single-pod).  One subprocess per
cell (fresh XLA device state; bounded memory); resumable — cells whose JSON
already reports OK/SKIP are not re-run.

    python scripts/dryrun_sweep.py [--only-missing] [--probes-only]
"""
import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "experiments", "dryrun")

ARCHS = ["hymba-1.5b", "yi-6b", "llama3-8b", "qwen1.5-4b", "granite-3-8b",
         "whisper-large-v3", "kimi-k2-1t-a32b", "llama4-scout-17b-a16e",
         "chameleon-34b", "mamba2-130m"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def done(tag: str) -> bool:
    p = os.path.join(OUT, tag + ".json")
    if not os.path.exists(p):
        return False
    try:
        with open(p) as f:
            return json.load(f).get("status") in ("OK", "SKIP")
    except Exception:
        return False


def run(arch, shape, multi_pod=False, probe=None, timeout=1800):
    mesh = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape}_{mesh}" + (f"_probe{probe}" if probe else "")
    if done(tag):
        print(f"[skip] {tag}", flush=True)
        return True
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    if probe:
        cmd += ["--probe-layers", str(probe)]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    t0 = time.time()
    try:
        r = subprocess.run(cmd, env=env, timeout=timeout,
                           capture_output=True, text=True)
        out = (r.stdout + r.stderr).strip().splitlines()
        msg = out[-1] if out else "(no output)"
    except subprocess.TimeoutExpired:
        msg = "TIMEOUT"
    print(f"[{time.time()-t0:6.1f}s] {tag}: {msg[:160]}", flush=True)
    return done(tag)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probes-only", action="store_true")
    ap.add_argument("--full-only", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    t0 = time.time()
    fails = []
    for arch in ARCHS:
        for shape in SHAPES:
            jobs = []
            if not args.probes_only:
                jobs.append(dict(multi_pod=False))
                jobs.append(dict(multi_pod=True))
            if not args.full_only:
                jobs.append(dict(probe=2))
                jobs.append(dict(probe=4))
            for j in jobs:
                ok = run(arch, shape, **j)
                if not ok:
                    fails.append((arch, shape, j))
    print(f"\nsweep done in {(time.time()-t0)/60:.1f} min; "
          f"{len(fails)} failures")
    for f in fails:
        print("FAIL:", f)


if __name__ == "__main__":
    main()
