#!/usr/bin/env python
"""Aggregate a flight-recorder JSONL trace into operator reports.

    PYTHONPATH=src python scripts/trace_report.py TRACE.jsonl
    PYTHONPATH=src python scripts/trace_report.py TRACE.jsonl --json

Produces, from the event stream alone (no live engine needed):

* **per-family dispatch histograms** — resolutions by bucket, deciding
  source, surface, and walk rank (how often dispatch fell past the top
  pick);
* **swap/demote timeline** — every provenance transition in tick order;
* **tick-latency percentiles** — p50/p90/p99 over ``TickSpan`` durations
  (tick indices are the timestamps; durations come from the engine's
  injectable clock);
* **staleness/drift report** — per family: demotions, hot-swaps,
  exhausted-ladder resets, and off-top-rank resolutions — the "is the
  offline ranking still right for this host/traffic?" signal;
* **reconstructed counters** — admissions/preemptions/sheds/cancels/
  poisons, fault firings by site, prefix-hit totals.  ``scripts/
  ci_obs.py`` asserts these equal the live stats dataclasses.

``aggregate(records)`` is importable; the CLI wraps it.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from typing import Any, Dict, Iterable, List, Mapping


def _percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = max(0, min(len(xs) - 1, -(-int(p * len(xs)) // 100) - 1))
    return xs[k]


def aggregate(records: Iterable[Mapping[str, Any]]) -> Dict[str, Any]:
    """Fold an event stream (dicts, as parsed from JSONL) into the report
    structure.  Pure and deterministic: same records, same output."""
    dispatch: Dict[str, Dict[str, Counter]] = {}
    timeline: List[Dict[str, Any]] = []
    durations: List[float] = []
    ticks = Counter()
    sched = Counter()
    faults = Counter()
    prefix = Counter()
    drift: Dict[str, Counter] = {}
    n = 0
    for rec in records:
        n += 1
        et = rec.get("etype")
        if et == "dispatch_decision":
            fam = dispatch.setdefault(rec["family"], {
                "by_bucket": Counter(), "by_source": Counter(),
                "by_surface": Counter(), "by_rank": Counter()})
            fam["by_bucket"][rec["bucket"] or "(warm)"] += 1
            fam["by_source"][rec["source"]] += 1
            fam["by_surface"][rec["surface"]] += 1
            fam["by_rank"][str(rec["rank"])] += 1
            if rec["rank"] > 0:
                drift.setdefault(rec["family"], Counter())["off_top"] += 1
        elif et in ("swap", "degrade"):
            d = drift.setdefault(rec["family"], Counter())
            d["swaps" if et == "swap" else "demotions"] += 1
            if rec.get("exhausted"):
                d["exhausted_resets"] += 1
            timeline.append({
                "tick": rec["tick"], "seq": rec["seq"], "kind": et,
                "family": rec["family"],
                "old": rec["old"][1], "new": rec["new"][1],
                "detail": (f"{rec['windows']} windows" if et == "swap"
                           else rec["source"])})
        elif et == "tick_span":
            durations.append(float(rec["duration_us"]))
            for k in ("admitted", "prefill_tokens", "decode_rows",
                      "preempted", "cancelled", "finished"):
                ticks[k] += rec[k]
            ticks["spans"] += 1
        elif et == "admission_decision":
            sched[rec["action"]] += 1
        elif et == "fault_fired":
            faults[f"{rec['site']}:{rec['kind']}"] += 1
            faults["total"] += 1
        elif et == "prefix_hit":
            prefix["hits"] += 1
            prefix["blocks"] += rec["blocks"]
            prefix["tokens_saved"] += rec["tokens"]
    timeline.sort(key=lambda e: (e["tick"], e["seq"]))
    return {
        "events": n,
        "dispatch": {f: {k: dict(c) for k, c in hists.items()}
                     for f, hists in sorted(dispatch.items())},
        "timeline": timeline,
        "ticks": {
            **{k: int(v) for k, v in sorted(ticks.items())},
            "p50_us": _percentile(durations, 50),
            "p90_us": _percentile(durations, 90),
            "p99_us": _percentile(durations, 99),
        },
        "sched": {k: int(v) for k, v in sorted(sched.items())},
        "faults": {k: int(v) for k, v in sorted(faults.items())},
        "prefix": {k: int(v) for k, v in sorted(prefix.items())},
        "drift": {f: {k: int(v) for k, v in sorted(c.items())}
                  for f, c in sorted(drift.items())},
    }


def load_records(path: str) -> List[Dict[str, Any]]:
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def _render(rep: Dict[str, Any]) -> str:
    out = [f"trace: {rep['events']} events"]
    t = rep["ticks"]
    if t.get("spans"):
        out.append(
            f"ticks: {t['spans']} spans, latency p50={t['p50_us']:.1f}us "
            f"p90={t['p90_us']:.1f}us p99={t['p99_us']:.1f}us; "
            f"admitted={t['admitted']} prefill_tokens={t['prefill_tokens']} "
            f"decode_rows={t['decode_rows']} preempted={t['preempted']} "
            f"cancelled={t['cancelled']} finished={t['finished']}")
    if rep["sched"]:
        out.append("sched: " + " ".join(f"{k}={v}" for k, v in
                                        rep["sched"].items()))
    if rep["prefix"]:
        p = rep["prefix"]
        out.append(f"prefix: hits={p.get('hits', 0)} "
                   f"blocks={p.get('blocks', 0)} "
                   f"tokens_saved={p.get('tokens_saved', 0)}")
    if rep["faults"]:
        out.append("faults: " + " ".join(
            f"{k}={v}" for k, v in rep["faults"].items() if k != "total"))
    for fam, hists in rep["dispatch"].items():
        srcs = " ".join(f"{k}={v}" for k, v in
                        sorted(hists["by_source"].items()))
        ranks = " ".join(f"r{k}={v}" for k, v in
                         sorted(hists["by_rank"].items()))
        out.append(f"dispatch {fam}: {srcs} | {ranks}")
        for bucket, cnt in sorted(hists["by_bucket"].items()):
            out.append(f"  {bucket}: {cnt}")
    if rep["drift"]:
        out.append("drift:")
        for fam, c in rep["drift"].items():
            out.append("  " + fam + ": " + " ".join(
                f"{k}={v}" for k, v in c.items()))
    if rep["timeline"]:
        out.append("timeline:")
        for ev in rep["timeline"]:
            out.append(f"  tick {ev['tick']}: {ev['kind']} {ev['family']} "
                       f"{ev['old']} -> {ev['new']} ({ev['detail']})")
    return "\n".join(out)


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="flight-recorder JSONL file")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregate as JSON instead of text")
    args = ap.parse_args(argv)
    rep = aggregate(load_records(args.trace))
    if args.json:
        print(json.dumps(rep, indent=2, sort_keys=True))
    else:
        print(_render(rep))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
