#!/usr/bin/env python
"""CI gate for the chaos-injection harness (PR 9): seeded fault schedules
through the smoke model must drain token-exact.

Three drills, mirroring the acceptance sweep in ``tests/test_faults.py``
but standalone so CI runs it against an installed tree in seconds:

1. **Parity sweep** — ``--seeds`` deterministic schedules
   (``FaultSchedule.random``) over every engine injection site
   (``pool.alloc``, ``serve.cow``, ``serve.prefill``, ``serve.decode``,
   ``serve.tick``); each drained run must be token-exact against the
   fault-free reference, with the KV-pool invariants re-proved every tick.
2. **Degrade drill** — a kernel-call failure under a frozen warm plan must
   demote a pick down the candidate ranking (>= 1 DegradeEvent) and still
   produce the reference tokens.
3. **Fatal drill** — an unrecoverable fault must propagate loudly, with
   the engine still drainable afterwards.

The parity sweep and degrade drill run under an installed flight
recorder (``repro.obs``): every fired fault, demotion, and preemption
must land in the trace with a matching tick id, and tracing must not
perturb token parity.

Exits non-zero on the first violated property.

    python scripts/ci_chaos.py [--seeds 6] [--config yi_6b]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

ENGINE_SITES = ("pool.alloc", "serve.cow", "serve.prefill", "serve.decode",
                "serve.tick")


def _fail(msg: str) -> int:
    print(f"[CI-CHAOS FAIL] {msg}", file=sys.stderr)
    return 1


def _build_engine(cfg, params, **kw):
    """Fresh engine over a fresh dispatch cache (demotions must not leak
    between drills — each run starts from the pristine ranking)."""
    from repro.artifacts.dispatch import DispatchCache, set_default_cache
    from repro.runtime import ServeEngine
    set_default_cache(DispatchCache())
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(cfg, params, **kw)


def _chaos_prompts(cfg):
    """A leader plus followers sharing its first 22 tokens: 22 % 4 != 0
    diverges mid-block, so followers map a partial tail block and the
    scheduler plans real CoW copies — the ``serve.cow`` site runs."""
    rng = np.random.default_rng(1234)
    lead = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    follows = [np.concatenate([lead[:22], rng.integers(0, cfg.vocab, 6)]
                              ).astype(np.int32) for _ in range(2)]
    return [lead] + follows


def _drain_checked(eng, max_ticks=300):
    """run_until_drained with the pool invariants re-proved every tick."""
    done = []
    for _ in range(max_ticks):
        done.extend(eng.step())
        eng.pool.check_invariants(
            block_tables=[s.blocks for s in eng.sched.running()])
        if not eng.sched.has_work():
            break
    while eng._inflight:
        done.extend(eng._commit(eng._inflight.popleft()))
    return done


def _provenance_errors(rec, inj, eng):
    """Completeness check for a traced drill: every fault the injector
    fired, every demotion the cache logged, and every preemption the
    scheduler counted must appear in the flight-recorder stream, each
    stamped with the engine tick it happened on."""
    from repro.runtime.faults import ANY_TICK
    recs = [json.loads(ln) for ln in rec.export_jsonl().splitlines() if ln]
    fired = sorted((s.site, s.kind) for s in inj.fired)
    fault_recs = [r for r in recs if r["etype"] == "fault_fired"]
    if fired != sorted((r["site"], r["kind"]) for r in fault_recs):
        return f"fault firings missing from trace (fired={inj.fired})"
    traced_at = {(r["site"], r["kind"], r["tick"]) for r in fault_recs}
    for s in inj.fired:
        if s.tick != ANY_TICK and (s.site, s.kind, s.tick) not in traced_at:
            return f"fault {s} traced at the wrong tick"
    want = sorted((ev.family, ev.tick) for ev in eng.degrade_events)
    got = sorted((r["family"], r["tick"]) for r in recs
                 if r["etype"] == "degrade")
    if want != got:
        return f"demotions missing from trace: events={want} trace={got}"
    preempts = sum(1 for r in recs if r["etype"] == "admission_decision"
                   and r["action"] == "preempt")
    if preempts != eng.sched.stats.preemptions:
        return (f"preemptions diverge: trace={preempts} "
                f"stats={eng.sched.stats.preemptions}")
    return None


def _staged_run(eng, prompts, *, max_new=5):
    """Leader first (populating the prefix index), then the followers —
    mid-block divergence then forces CoW.  Returns {rid: tokens}."""
    outs = {}
    eng.submit(prompts[0], max_new=max_new)
    for r in _drain_checked(eng):
        outs[r.rid] = list(r.out)
    for p in prompts[1:]:
        eng.submit(p, max_new=max_new)
    for r in _drain_checked(eng):
        outs[r.rid] = list(r.out)
    return outs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seeds", type=int, default=6,
                    help="number of random schedules in the parity sweep")
    ap.add_argument("--config", default="yi_6b",
                    help="config whose smoke variant the drills serve")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.obs import tracing
    from repro.runtime import faults
    from repro.runtime.faults import (ANY_TICK, FatalFault, FaultSchedule,
                                      FaultSpec)

    cfg = get_smoke_config(args.config)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prompts = _chaos_prompts(cfg)

    # 1. parity sweep against the fault-free reference
    ref_eng = _build_engine(cfg, params, prefix_sharing=True)
    ref = _staged_run(ref_eng, prompts)
    if ref_eng.pool.stats.cow_copies < 1:
        return _fail("reference workload never exercised the CoW site")

    total_fired = 0
    for seed in range(args.seeds):
        schedule = FaultSchedule.random(seed, sites=ENGINE_SITES,
                                        max_tick=24, n=4)
        eng = _build_engine(cfg, params, prefix_sharing=True, degrade=True)
        with tracing(capacity=1 << 16) as rec:
            with faults.inject(schedule) as inj:
                got = _staged_run(eng, prompts)
        if got != ref:
            return _fail(f"seed {seed} diverged from the fault-free "
                         f"reference (schedule={list(schedule)}, "
                         f"fired={inj.fired})")
        err = _provenance_errors(rec, inj, eng)
        if err:
            return _fail(f"seed {seed} trace incomplete: {err}")
        total_fired += len(inj.fired)
        print(f"[ci-chaos] seed {seed}: parity ok, "
              f"{len(inj.fired)} fault(s) fired | {eng.robustness_line()}")
    if total_fired == 0:
        return _fail("parity sweep fired no faults — the schedules never "
                     "hit the workload's sites/ticks")

    # 2. degrade drill: frozen warm plan, kernel failure -> demotion
    warm_ref_eng = _build_engine(cfg, params, warm_kernels=True)
    for p in prompts:
        warm_ref_eng.submit(p, max_new=5)
    warm_ref = {r.rid: list(r.out) for r in _drain_checked(warm_ref_eng)}

    eng = _build_engine(cfg, params, warm_kernels=True, degrade=True)
    for p in prompts:
        eng.submit(p, max_new=5)
    with tracing(capacity=1 << 16) as rec:
        with faults.inject([FaultSpec("serve.prefill", ANY_TICK, "error"),
                            FaultSpec("serve.decode", ANY_TICK, "error")]
                           ) as inj:
            got = {r.rid: list(r.out) for r in _drain_checked(eng)}
    if got != warm_ref:
        return _fail("degrade drill diverged from the fault-free reference")
    if len(eng.degrade_events) < 1:
        return _fail("degrade drill recorded no DegradeEvent")
    err = _provenance_errors(rec, inj, eng)
    if err:
        return _fail(f"degrade drill trace incomplete: {err}")
    print(f"[ci-chaos] degrade drill: parity ok, "
          f"{len(eng.degrade_events)} demotion event(s) traced | "
          f"{eng.robustness_line()}")

    # 3. fatal drill: loud failure, engine still drainable
    eng = _build_engine(cfg, params, degrade=True)
    for p in prompts:
        eng.submit(p, max_new=4)
    raised = False
    with faults.inject([FaultSpec("serve.decode", ANY_TICK, "fatal")]):
        try:
            for _ in range(100):
                eng.step()
                if not eng.sched.has_work():
                    break
        except FatalFault:
            raised = True
    if not raised:
        return _fail("fatal fault did not propagate out of the engine")
    done = _drain_checked(eng)
    if len(done) != len(prompts) or any(len(r.out) != 4 for r in done):
        return _fail("engine did not drain to completion after the fatal "
                     "fault")
    print("[ci-chaos] fatal drill: raised loudly, engine drained clean")

    print(f"[CI-CHAOS OK] {args.seeds} seeded schedules token-exact, "
          f"{total_fired} fault(s) fired, degradation + fatal semantics "
          f"hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
