#!/usr/bin/env python
"""Tune dispatch tables against measured hardware: measure -> calibrate ->
compact -> rewrite.

Loads each (family, machine) dispatch table (compiling it first when absent),
times the top-k pre-ranked candidates per data-shape bucket on real or
interpreted Pallas (deterministic seeds, trimmed-mean over repeats), fits the
KLARAPTOR-style per-family calibration, computes the "few fit most" variant
subset, and rewrites the table in place with the optional FORMAT_VERSION-2
sections (``calibration``, ``measured_ranks``, ``compaction``).  The runtime
``DispatchCache`` then prefers the measured order; untuned tables keep
resolving symbolically.  See docs/tuning.md for the full workflow.

    PYTHONPATH=src python scripts/tune_artifacts.py \
        --family matmul --machine tpu_v5e --out artifacts
    PYTHONPATH=src python scripts/tune_artifacts.py --dry-run   # CI smoke
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.artifacts import ArtifactStore, compile_family      # noqa: E402
from repro.core.params import MACHINES                          # noqa: E402
from repro.tuning import MeasureConfig, calibrate_table, \
    compact_table, measure_table                                # noqa: E402
from repro.tuning.compact import compaction_summary             # noqa: E402
from repro.tuning.measure import measure_shape, parse_bucket_key  # noqa: E402


def _load_or_compile(store, family, machine, quick):
    table = store.load_dispatch(family.name, machine.name)
    if table is None:
        print(f"[compile] no dispatch table for {family.name}/{machine.name}"
              f" under {store.root}; compiling", flush=True)
        compile_family(family, store, machines=[machine], quick=quick)
        table = store.load_dispatch(family.name, machine.name)
    return table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--family", action="append", default=None,
                    help="kernel family to tune (repeatable; default all)")
    ap.add_argument("--machine", action="append", default=None,
                    choices=sorted(MACHINES),
                    help="target machine (repeatable; default all)")
    ap.add_argument("--out", default=None,
                    help="artifact root (default: $REPRO_ARTIFACT_DIR "
                         "or ./artifacts)")
    ap.add_argument("--iters", type=int, default=3,
                    help="timed repeats per candidate")
    ap.add_argument("--warmup", type=int, default=1,
                    help="untimed warm-up runs per candidate")
    ap.add_argument("--trim", type=int, default=1,
                    help="repeats trimmed from each end before the mean")
    ap.add_argument("--top-k", type=int, default=4,
                    help="candidates measured per bucket (prefix of the "
                         "table's symbolic ranking)")
    ap.add_argument("--max-dim", type=int, default=256,
                    help="clamp measured data dims (interpreted Pallas pays "
                         "per grid step on CPU; raise on a real TPU)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="few-fit-most relative tolerance vs per-bucket best")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for deterministic operand tensors")
    ap.add_argument("--no-interpret", action="store_true",
                    help="run kernels compiled (requires a real TPU backend)")
    ap.add_argument("--quick", action="store_true",
                    help="when compiling a missing table, build one bucket")
    ap.add_argument("--dry-run", action="store_true",
                    help="resolve tables and list the measurement plan "
                         "without running any kernel (CI smoke)")
    args = ap.parse_args(argv)

    from repro.artifacts.compile import registered_families
    registry = registered_families()
    names = args.family if args.family else sorted(registry)
    unknown = [n for n in names if n not in registry]
    if unknown:
        ap.error(f"unknown kernel family {unknown}; have {sorted(registry)}")
    machines = [MACHINES[m] for m in (args.machine or sorted(MACHINES))]
    store = ArtifactStore(args.out)
    cfg = MeasureConfig(iters=args.iters, warmup=args.warmup, trim=args.trim,
                        max_dim=args.max_dim, top_k=args.top_k,
                        seed=args.seed, interpret=not args.no_interpret)
    meta = {"iters": cfg.iters, "warmup": cfg.warmup, "trim": cfg.trim,
            "max_dim": cfg.max_dim, "top_k": cfg.top_k, "seed": cfg.seed,
            "interpret": cfg.interpret}

    failures = 0
    for name in names:
        family = registry[name]
        for machine in machines:
            t0 = time.perf_counter()
            table = _load_or_compile(store, family, machine, args.quick)
            if table is None:
                print(f"[FAIL] {name}/{machine.name}: could not load or "
                      f"compile a dispatch table", file=sys.stderr)
                failures += 1
                continue
            buckets = table.get("buckets", {})
            plan_rows = sum(min(len(v), cfg.top_k) for v in buckets.values())
            if args.dry_run:
                print(f"[dry-run] {name}/{machine.name}: "
                      f"{len(buckets)} buckets, {plan_rows} candidate "
                      f"timings planned (top-{cfg.top_k}, "
                      f"max_dim={cfg.max_dim})")
                for b in sorted(buckets):
                    head = buckets[b][:cfg.top_k]
                    try:
                        shape = measure_shape(
                            name, parse_bucket_key(b),
                            [e["assignment"] for e in head], cfg.max_dim)
                    except (KeyError, TypeError, ValueError):
                        # same tolerance as measure_table: a mangled bucket
                        # is skipped, not a crash
                        print(f"           {b} -> skipped (unparseable)")
                        continue
                    print(f"           {b} -> measure at {shape} "
                          f"({len(head)} candidates)")
                continue
            samples = measure_table(
                family, table, cfg,
                progress=lambda s: print(f"  [measure] {s}", flush=True))
            ok = [s for s in samples if s.us is not None]
            tuned = calibrate_table(family, table, samples, meta=meta)
            tuned = compact_table(tuned, samples, tolerance=args.tolerance)
            path = store.save_dispatch(tuned)
            cal = tuned.get("calibration")
            fit_line = ("no fit (too few samples)" if cal is None else
                        f"fit n={cal['n_samples']} "
                        f"rms_log_resid={cal['rms_log_residual']:.3f} "
                        f"top1_agreement={cal['top1_agreement']}")
            print(f"[OK] {name}/{machine.name}: {len(ok)}/{len(samples)} "
                  f"candidates measured across {len(buckets)} buckets "
                  f"({time.perf_counter() - t0:.1f}s)\n"
                  f"     {fit_line}\n"
                  f"     compaction: {compaction_summary(tuned)}\n"
                  f"     -> {path}", flush=True)
            if not ok:
                print(f"[FAIL] {name}/{machine.name}: every measurement "
                      f"failed", file=sys.stderr)
                failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
