#!/usr/bin/env python
"""CI gate for the plan-staleness check (PR 8): prove BOTH exit paths.

Takes an artifact root that already holds a serve plan AND the dispatch
tables it was built against (the `plan_artifacts.py --out` from the
preceding CI step), then:

1. re-tunes one matmul bucket score in the dispatch table — the canonical
   "somebody re-ran scripts/tune_artifacts.py after the plan was built"
   drift scenario;
2. asserts ``plan_artifacts.py --check`` reports STALE but still exits 0
   (the warn path: serving falls back to online resolution);
3. asserts ``plan_artifacts.py --check --strict`` exits NON-zero (the
   refuse path: --strict-plans serving would abort at start);
4. restores the original table bytes, so the artifact dir uploaded
   afterwards is the real, fresh one.

Exits non-zero if either path misbehaves.

    python scripts/ci_stale_plan.py --out artifacts \
        [--config llama3_8b] [--machine tpu_v5e]
"""
from __future__ import annotations

import argparse
import subprocess
import sys
from pathlib import Path

SCRIPTS = Path(__file__).resolve().parent


def run_check(out: str, config: str, machine: str, *, strict: bool):
    cmd = [sys.executable, str(SCRIPTS / "plan_artifacts.py"),
           "--config", config, "--machine", machine, "--out", out,
           "--check"] + (["--strict"] if strict else [])
    proc = subprocess.run(cmd, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    return proc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", required=True,
                    help="artifact root holding the plan + dispatch tables")
    ap.add_argument("--config", default="llama3_8b")
    ap.add_argument("--machine", default="tpu_v5e")
    ap.add_argument("--family", default="matmul",
                    help="family whose table gets deliberately re-tuned")
    args = ap.parse_args(argv)

    from repro.artifacts import ArtifactStore
    store = ArtifactStore(args.out)
    table = store.dispatch_path(args.family, args.machine)
    if not table.exists():
        print(f"[CI-STALE FAIL] no dispatch table at {table} — build "
              f"artifacts before running this gate", file=sys.stderr)
        return 1
    original = table.read_bytes()

    # drift: nudge one tuned score, exactly what a re-tune run would do
    payload = store.load_dispatch(args.family, args.machine)
    bucket = next(iter(payload["buckets"]))
    payload["buckets"][bucket][0]["score"] = \
        float(payload["buckets"][bucket][0]["score"]) + 1.0
    store.save_dispatch(payload)

    try:
        warn = run_check(args.out, args.config, args.machine, strict=False)
        if warn.returncode != 0:
            print(f"[CI-STALE FAIL] warn-mode --check exited "
                  f"{warn.returncode}, expected 0", file=sys.stderr)
            return 1
        if "[STALE]" not in warn.stdout:
            print("[CI-STALE FAIL] warn-mode --check did not report STALE "
                  "for a re-tuned table", file=sys.stderr)
            return 1
        strict = run_check(args.out, args.config, args.machine, strict=True)
        if strict.returncode == 0:
            print("[CI-STALE FAIL] strict-mode --check exited 0 for a "
                  "stale plan, expected non-zero", file=sys.stderr)
            return 1
    finally:
        table.write_bytes(original)

    fresh = run_check(args.out, args.config, args.machine, strict=True)
    if fresh.returncode != 0:
        print("[CI-STALE FAIL] restored table still reads stale — "
              "restore failed?", file=sys.stderr)
        return 1
    print("[CI-STALE OK] warn path exits 0, strict path refuses, "
          "restore reads fresh")
    return 0


if __name__ == "__main__":
    sys.exit(main())
