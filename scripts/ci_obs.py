#!/usr/bin/env python
"""CI gate for the observability layer (repro.obs): serve the smoke model
with tracing on, then prove three properties of the exported trace:

1. **Schema** — every emitted JSONL line validates against the event
   schema (``repro.obs.events.EVENT_SCHEMA``): known etype, every field
   present and well-typed, no extras.
2. **Provenance completeness** — ``scripts/trace_report.py`` aggregation
   over the trace reconstructs exactly the counts the live stats
   dataclasses report: admissions/preemptions/sheds/cancels/poisons
   (``SchedStats``), prefix hits (``PoolStats``), demotions
   (``DispatchStats`` + ``degrade_events``), fault firings (the
   injector's ``fired`` log), and one ``tick_span`` per engine tick.
3. **Determinism** — a re-run with the same seed, schedule, and injected
   counting clock produces a byte-identical JSONL export (timestamps are
   tick indices; wall clock never reaches the trace).

Exits non-zero on the first violated property.

    python scripts/ci_obs.py [--config yi_6b]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402

from trace_report import aggregate  # noqa: E402


def _fail(msg: str) -> int:
    print(f"[CI-OBS FAIL] {msg}", file=sys.stderr)
    return 1


class _CountingClock:
    """Deterministic monotonic clock: every read advances 0.1 ms.  The
    engine's only wall-clock uses (watchdog, deadlines, TickSpan
    durations) go through it, so the trace is seed-exact."""

    def __init__(self) -> None:
        self.now = 0.0

    def __call__(self) -> float:
        self.now += 1e-4
        return self.now


def _prompts(cfg):
    """A leader plus followers sharing its first 20 tokens (page-aligned
    at page_size=4), so the second stage maps prefix blocks."""
    rng = np.random.default_rng(4321)
    lead = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    follows = [np.concatenate([lead[:20], rng.integers(0, cfg.vocab, 8)]
                              ).astype(np.int32) for _ in range(3)]
    return [lead] + follows


def _run(cfg, params, schedule):
    """One traced, fault-injected serve of the smoke workload over fresh
    everything (cache, pool, recorder, clock).  Returns (jsonl, stats)."""
    from repro.artifacts.dispatch import DispatchCache, set_default_cache
    from repro.obs import tracing
    from repro.runtime import ServeEngine, faults

    set_default_cache(DispatchCache())
    eng = ServeEngine(cfg, params, max_batch=3, max_len=64, page_size=4,
                      num_blocks=20, prefill_chunk=8, prefix_sharing=True,
                      warm_kernels=True, plan_store=False, degrade=True,
                      max_queue=4, clock=_CountingClock())
    prompts = _prompts(cfg)
    with tracing(capacity=1 << 16, sample_frozen_every=8) as rec:
        with faults.inject(schedule) as inj:
            eng.submit(prompts[0], max_new=5)
            eng.run_until_drained()
            for p in prompts[1:]:
                eng.submit(p, max_new=5)
            # expire one request immediately for the cancel path, then
            # over-submit past max_queue to exercise the shed path
            eng.submit(prompts[0], max_new=5, deadline_ms=0.0)
            for p in prompts[1:]:
                eng.submit(p, max_new=5)
            eng.run_until_drained()
        jsonl = rec.export_jsonl()
    stats = {
        "sched": eng.sched.stats, "pool": eng.pool.stats,
        "cache": eng._cache, "fired": list(inj.fired),
        "ticks": eng.sched.ticks, "recorder": rec,
    }
    return jsonl, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--config", default="yi_6b",
                    help="config whose smoke variant is served")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.obs.events import validate_record
    from repro.runtime.faults import ANY_TICK, FaultSpec

    cfg = get_smoke_config(args.config)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    # one recoverable kernel fault (degrade -> demotion) + one injected
    # pool exhaustion — both must land in the trace
    schedule = [FaultSpec("serve.decode", ANY_TICK, "error"),
                FaultSpec("pool.alloc", ANY_TICK, "exhaust")]

    jsonl, st = _run(cfg, params, schedule)
    lines = [ln for ln in jsonl.splitlines() if ln]
    if not lines:
        return _fail("traced serve produced an empty event stream")
    for i, line in enumerate(lines):
        try:
            validate_record(json.loads(line))
        except (ValueError, KeyError, TypeError) as e:
            return _fail(f"line {i} failed schema validation: {e}\n  {line}")
    print(f"[ci-obs] schema: {len(lines)} lines valid "
          f"({st['recorder'].dropped} dropped)")

    rep = aggregate(json.loads(ln) for ln in lines)
    sched, pool, cache = st["sched"], st["pool"], st["cache"]
    demotions = sum(c.get("demotions", 0) for c in rep["drift"].values())
    checks = [
        ("admit", rep["sched"].get("admit", 0), sched.admissions),
        ("preempt", rep["sched"].get("preempt", 0), sched.preemptions),
        ("wait", rep["sched"].get("wait", 0), sched.admission_waits),
        ("shed", rep["sched"].get("shed", 0), sched.shed),
        ("cancel", rep["sched"].get("cancel", 0), sched.cancelled),
        ("poison", rep["sched"].get("poison", 0), sched.poisoned),
        ("prefix blocks", rep["prefix"].get("blocks", 0), pool.prefix_hits),
        ("prefix tokens", rep["prefix"].get("tokens_saved", 0),
         pool.prefix_tokens_saved),
        ("demotions", demotions, cache.stats.demotions),
        ("degrade events", demotions, len(cache.degrade_events)),
        ("faults", rep["faults"].get("total", 0), len(st["fired"])),
        ("tick spans", rep["ticks"].get("spans", 0), st["ticks"]),
    ]
    for name, got, want in checks:
        if got != want:
            return _fail(f"count mismatch: trace {name}={got}, "
                         f"stats say {want}")
    if sched.shed < 1 or sched.cancelled < 1 or cache.stats.demotions < 1:
        return _fail("workload failed to exercise shed/cancel/demote "
                     f"(shed={sched.shed} cancelled={sched.cancelled} "
                     f"demotions={cache.stats.demotions})")
    # every demotion and fault firing must carry a matching tick id
    by_tick = {(e["kind"], e["tick"]) for e in rep["timeline"]}
    for ev in cache.degrade_events:
        if ("degrade", ev.tick) not in by_tick:
            return _fail(f"demotion at tick {ev.tick} missing from trace")
    fault_recs = [json.loads(ln) for ln in lines
                  if json.loads(ln)["etype"] == "fault_fired"]
    fired_sites = sorted((s.site, s.kind) for s in st["fired"])
    traced_sites = sorted((r["site"], r["kind"]) for r in fault_recs)
    if fired_sites != traced_sites:
        return _fail(f"fault firings diverge: injector={fired_sites} "
                     f"trace={traced_sites}")
    print(f"[ci-obs] completeness: {len(checks)} counters reconstruct, "
          f"{demotions} demotion(s) + {len(fault_recs)} fault(s) "
          f"tick-matched")

    jsonl2, _ = _run(cfg, params, schedule)
    if jsonl2 != jsonl:
        a, b = jsonl.splitlines(), jsonl2.splitlines()
        diff = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
                    min(len(a), len(b)))
        return _fail(f"re-run trace is not byte-identical (first "
                     f"divergence at line {diff}: "
                     f"{a[diff] if diff < len(a) else '<eof>'!r} vs "
                     f"{b[diff] if diff < len(b) else '<eof>'!r})")
    print(f"[ci-obs] determinism: re-run byte-identical "
          f"({len(jsonl)} bytes)")

    print(f"[CI-OBS OK] {len(lines)} events: schema valid, counters "
          f"reconstruct, trace byte-deterministic")
    return 0


if __name__ == "__main__":
    sys.exit(main())
