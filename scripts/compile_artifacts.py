#!/usr/bin/env python
"""Compile comprehensive-optimization artifacts offline.

Builds the case-discussion tree for each kernel family, serializes it, and
emits per-machine dispatch tables with pre-ranked candidates per data-shape
bucket.  Ship the output directory with the model weights; at load time the
runtime resolves every kernel-variant decision with a table lookup instead of
a tree search (set ``REPRO_ARTIFACT_DIR`` or run from the directory holding
``artifacts/``).

    PYTHONPATH=src python scripts/compile_artifacts.py                 # all
    PYTHONPATH=src python scripts/compile_artifacts.py --family matmul \
        --machine tpu_v5e --out artifacts --verify
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.artifacts import ArtifactStore, compile_all          # noqa: E402
from repro.core.comprehensive import comprehensive_optimization  # noqa: E402
from repro.core.params import MACHINES                           # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--family", action="append", default=None,
                    help="kernel family to compile (repeatable; default all)")
    ap.add_argument("--machine", action="append", default=None,
                    choices=sorted(MACHINES),
                    help="target machine (repeatable; default all)")
    ap.add_argument("--out", default=None,
                    help="artifact root (default: $REPRO_ARTIFACT_DIR "
                         "or ./artifacts)")
    ap.add_argument("--top-k", type=int, default=8,
                    help="pre-ranked candidates kept per data-shape bucket")
    ap.add_argument("--quick", action="store_true",
                    help="one data-shape bucket per family (CI smoke)")
    ap.add_argument("--verify", action="store_true",
                    help="reload each tree and check leaf-for-leaf equality "
                         "against a fresh in-process build")
    args = ap.parse_args(argv)

    store = ArtifactStore(args.out)
    machines = ([MACHINES[m] for m in args.machine] if args.machine else None)
    try:
        reports = compile_all(store, families=args.family, machines=machines,
                              top_k=args.top_k, quick=args.quick)
    except KeyError as e:
        ap.error(str(e.args[0] if e.args else e))

    failures = 0
    for rep in reports:
        line = (f"[OK] {rep['family']}: {rep['leaves']} leaves "
                f"digest={rep['tree_digest']} ({rep['seconds']}s)")
        for mname, d in rep["dispatch"].items():
            line += (f"\n     {mname}: {d['kept_leaves']} leaves, "
                     f"{d['buckets']} buckets -> {d['path']}")
        print(line, flush=True)
        if args.verify:
            from repro.artifacts.compile import registered_families
            family = registered_families()[rep["family"]]
            reloaded = store.load_tree(rep["family"])
            fresh = comprehensive_optimization(family)
            if reloaded is None or reloaded != fresh:
                print(f"[VERIFY FAIL] {rep['family']}: reloaded tree != "
                      f"fresh build", file=sys.stderr)
                failures += 1
            else:
                print(f"     verify: reloaded == fresh "
                      f"({len(reloaded)} leaves)")
    print(f"compiled {len(reports)} families into {store.root}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
