#!/usr/bin/env python
"""Gate CI on benchmark regressions against a committed baseline.

Compares one or more ``benchmarks/run.py --json`` output files (rows are
merged; later files win name collisions) against
``benchmarks/baseline.json`` and exits non-zero when a gated row regresses
by more than ``--max-ratio`` (wall-time ratio, default 2.0).  Both missing
directions fail loudly:

- a baseline row with no measured counterpart (a renamed/dropped/not-run
  benchmark) — a silently skipped benchmark is a regression in itself;
- with ``--strict``, a measured row with no baseline counterpart — a new
  benchmark that nobody gates silently stops being a perf trajectory.

On failure the summary names the worst-ratio row, so the offender is
visible straight from the CI log instead of a by-hand JSON diff.  Rows
faster than the baseline print an invitation to ratchet the committed
number down.

Win or lose, a machine-readable per-row summary (every gated row with
its measured/baseline microseconds, ratio, and status; plus the worst
ratio and the failure count) is written next to the first measured file
as ``check_bench_summary.json`` (``--summary`` overrides) — CI uploads
it as an artifact so perf trajectories can be scraped across runs
without parsing the gate log.

    python scripts/check_bench.py BENCH_dispatch.json BENCH_serve_load.json \
        --baseline benchmarks/baseline.json \
        --key dispatch_cold_matmul --max-ratio 2.0 --strict
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_rows(paths) -> dict:
    rows: dict = {}
    for path in paths:
        with open(path) as f:
            payload = json.load(f)
        rows.update({row["name"]: row for row in payload.get("rows", [])})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("measured", nargs="+",
                    help="JSON file(s) from benchmarks/run.py --json "
                         "(rows merged; later files win collisions)")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--key", action="append", default=None,
                    help="row name to gate (repeatable; default: every key "
                         "in the baseline file)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when measured_us > ratio * baseline_us")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on measured rows absent from the "
                         "baseline (every benchmark the CI job runs must "
                         "be gated)")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="where to write the per-row ratio summary JSON "
                         "(default: check_bench_summary.json next to the "
                         "first measured file)")
    args = ap.parse_args(argv)

    measured = load_rows(args.measured)
    with open(args.baseline) as f:
        baseline = json.load(f)
    keys = args.key if args.key else sorted(baseline.get("rows", {}))

    failures = 0
    worst = None                           # (ratio, key, us, base_us)
    summary_rows = []
    for key in keys:
        base = baseline.get("rows", {}).get(key)
        if base is None:
            print(f"[GATE FAIL] {key}: not in baseline {args.baseline}",
                  file=sys.stderr)
            failures += 1
            summary_rows.append({"name": key, "status": "no_baseline"})
            continue
        row = measured.get(key)
        if row is None:
            print(f"[GATE FAIL] {key}: missing from measured file(s) "
                  f"(benchmark did not run?)", file=sys.stderr)
            failures += 1
            summary_rows.append({"name": key, "status": "not_measured",
                                 "baseline_us": float(base["us"])})
            continue
        us, base_us = float(row["us"]), float(base["us"])
        ratio = us / base_us if base_us > 0 else float("inf")
        if worst is None or ratio > worst[0]:
            worst = (ratio, key, us, base_us)
        ok = ratio <= args.max_ratio
        summary_rows.append({"name": key,
                             "status": "ok" if ok else "regressed",
                             "measured_us": us, "baseline_us": base_us,
                             "ratio": round(ratio, 4)})
        if not ok:
            print(f"[GATE FAIL] {key}: {us:.1f}us vs baseline "
                  f"{base_us:.1f}us ({ratio:.2f}x > {args.max_ratio:.2f}x)",
                  file=sys.stderr)
            failures += 1
        else:
            note = " (consider ratcheting the baseline down)" \
                if ratio < 0.5 else ""
            print(f"[GATE OK]   {key}: {us:.1f}us vs baseline "
                  f"{base_us:.1f}us ({ratio:.2f}x){note}")

    if args.strict:
        ungated = sorted(set(measured) - set(baseline.get("rows", {})))
        for key in ungated:
            print(f"[GATE FAIL] {key}: measured but absent from "
                  f"{args.baseline} (add a baseline row so it stays gated)",
                  file=sys.stderr)
            failures += 1
            summary_rows.append({"name": key, "status": "ungated",
                                 "measured_us": float(measured[key]["us"])})
    if failures and worst is not None:
        print(f"[GATE WORST] {worst[1]}: {worst[2]:.1f}us vs baseline "
              f"{worst[3]:.1f}us ({worst[0]:.2f}x) — the biggest measured "
              f"ratio this run", file=sys.stderr)

    summary_path = args.summary or os.path.join(
        os.path.dirname(os.path.abspath(args.measured[0])),
        "check_bench_summary.json")
    summary = {
        "baseline": args.baseline,
        "measured": list(args.measured),
        "max_ratio": args.max_ratio,
        "strict": bool(args.strict),
        "failures": failures,
        "worst": ({"name": worst[1], "measured_us": worst[2],
                   "baseline_us": worst[3], "ratio": round(worst[0], 4)}
                  if worst is not None else None),
        "rows": sorted(summary_rows, key=lambda r: r["name"]),
    }
    with open(summary_path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[GATE SUMMARY] {len(summary_rows)} row(s), "
          f"{failures} failure(s) -> {summary_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
