#!/usr/bin/env python
"""Markdown link check over docs/ + README — CI's dead-doc gate.

Scans every tracked markdown file for inline links/images
(``[text](target)``) and reference definitions (``[ref]: target``), and
fails when an *intra-repo* target does not exist on disk.  External URLs
(``http://``, ``https://``, ``mailto:``) are not fetched — this gate is
about the repo's own docs never pointing at files a refactor moved or
deleted.  Anchors (``path#section``) are checked for the file part only.

    python scripts/check_docs.py              # docs/ + README.md + ROADMAP.md
    python scripts/check_docs.py FILE.md ...  # explicit file list
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — but not [text](http://...); and footnote-style
# [ref]: target definitions at line start
_INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")

DEFAULT_FILES = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md")


def iter_targets(text: str):
    for m in _INLINE.finditer(text):
        yield m.group(1)
    for m in _REFDEF.finditer(text):
        yield m.group(1)


def check_file(md: Path) -> list:
    failures = []
    text = md.read_text(encoding="utf-8")
    for target in iter_targets(text):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        # leading "/" means repo-root-relative (GitHub convention), not
        # filesystem-absolute
        resolved = (ROOT / path_part.lstrip("/") if path_part.startswith("/")
                    else md.parent / path_part)
        try:
            resolved = resolved.resolve()
        except OSError:
            failures.append((md, target, "unresolvable"))
            continue
        if not resolved.exists():
            failures.append((md, target, "missing"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("files", nargs="*",
                    help="markdown files to check (default: docs/**/*.md "
                         "plus README.md, ROADMAP.md, CHANGES.md, PAPER.md)")
    args = ap.parse_args(argv)

    if args.files:
        files = [Path(f).resolve() for f in args.files]
    else:
        files = sorted((ROOT / "docs").glob("**/*.md"))
        files += [ROOT / name for name in DEFAULT_FILES
                  if (ROOT / name).exists()]
    missing_inputs = [f for f in files if not f.exists()]
    if missing_inputs:
        for f in missing_inputs:
            print(f"[DOCS FAIL] input file missing: {f}", file=sys.stderr)
        return 1

    failures = []
    checked = 0
    for md in files:
        failures.extend(check_file(md))
        checked += 1
    for md, target, why in failures:
        try:
            shown = md.relative_to(ROOT)
        except ValueError:
            shown = md
        print(f"[DOCS FAIL] {shown}: link -> {target!r} "
              f"({why})", file=sys.stderr)
    print(f"checked {checked} markdown files, "
          f"{len(failures)} dead intra-repo links")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
