#!/usr/bin/env python
"""Build portable serve-plan artifacts offline: trace -> resolve -> ship.

For each model config, traces the exact (family, machine, data) warm set its
serve path will dispatch (``repro.plans.trace`` — Mamba configs include
``ssd_scan``, MoE configs their router/expert projections, whisper the
encoder shapes), resolves every triple through the dispatch tiers against
the artifact dir (so compiled/tuned tables decide the picks), and writes a
versioned serve-plan artifact next to the dispatch tables:

    <out>/plans/<config>/serve-v<V>-<machine>.json

Ship the whole artifact dir to the serving mesh; every host's
``ServeEngine(warm_kernels=True)`` then starts from the plan with zero
online tree enumeration (``DispatchCache.stats.cold_builds == 0``).

    PYTHONPATH=src python scripts/plan_artifacts.py                # all archs
    PYTHONPATH=src python scripts/plan_artifacts.py --config llama3_8b \
        --machine tpu_v5e --out artifacts
    PYTHONPATH=src python scripts/plan_artifacts.py --config llama3_8b \
        --dry-run                                                  # CI smoke
    PYTHONPATH=src python scripts/plan_artifacts.py --config llama3_8b \
        --check [--strict]                    # staleness audit, no rebuild

``--check`` audits shipped plans instead of building: each plan's recorded
dispatch-table digests (PLAN_FORMAT_VERSION 3) are compared against the
tables currently under the artifact root — the same comparison engine start
performs.  Stale plans are reported; exit is 0 (warn mode, matching the
engine's warn-and-fall-back default) unless ``--strict`` is given, which
exits nonzero exactly like ``--strict-plans`` refuses to serve.
"""
from __future__ import annotations

import argparse
import collections
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.artifacts import ArtifactStore, DispatchCache      # noqa: E402
from repro.configs import ARCH_IDS, get_config, get_smoke_config  # noqa: E402
from repro.core.params import MACHINES                         # noqa: E402
from repro.plans import (PlanStore, build_serve_plan, plan_staleness,  # noqa: E402
                         trace_warm_set)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--config", action="append", default=None,
                    help="model config to plan (repeatable; module name or "
                         "canonical id; default: every assigned arch)")
    ap.add_argument("--machine", action="append", default=None,
                    choices=sorted(MACHINES),
                    help="target machine (repeatable; default tpu_v5e — "
                         "the serving target)")
    ap.add_argument("--out", default=None,
                    help="artifact root (default: $REPRO_ARTIFACT_DIR "
                         "or ./artifacts); dispatch tables found there "
                         "decide the resolutions")
    ap.add_argument("--max-len", type=int, default=512,
                    help="serve window the warm set is traced for")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged-KV block size the warm set is traced for "
                         "(0 = dense layout; must match the engine's "
                         "page_size for the plan to be a hit)")
    ap.add_argument("--include-train", action="store_true",
                    help="also trace the train-step shapes into the plan")
    ap.add_argument("--train-seq", type=int, default=4096)
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-scale dims)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print each config's traced warm set without "
                         "resolving or writing anything (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="audit shipped plans for digest staleness instead "
                         "of building (see module docstring)")
    ap.add_argument("--strict", action="store_true",
                    help="with --check: exit nonzero on any stale plan "
                         "(the --strict-plans refusal, offline)")
    args = ap.parse_args(argv)

    names = args.config if args.config else list(ARCH_IDS)
    get = get_smoke_config if args.smoke else get_config
    try:
        cfgs = [get(n) for n in names]
    except ModuleNotFoundError as e:
        ap.error(f"unknown config {e.name!r}; have {sorted(ARCH_IDS)}")
    machines = [MACHINES[m] for m in (args.machine or ["tpu_v5e"])]
    trace_kw = dict(max_len=args.max_len, page_size=args.page_size,
                    include_train=args.include_train,
                    train_seq=args.train_seq, train_batch=args.train_batch)

    if args.dry_run:
        for cfg in cfgs:
            traced = trace_warm_set(cfg, **trace_kw)
            fams = collections.Counter(op.family for op in traced)
            print(f"[dry-run] {cfg.name}: {len(traced)} traced triples "
                  f"({', '.join(f'{f}x{n}' for f, n in sorted(fams.items()))})")
            for op in traced:
                print(f"           {op.label}  <- {', '.join(op.sites)}")
        return 0

    if args.check:
        plan_store = PlanStore(args.out)
        dispatch_store = ArtifactStore(args.out) if args.out else None
        stale_count = 0
        for machine in machines:
            for cfg in cfgs:
                plan = plan_store.load_plan(cfg.name, machine.name)
                if plan is None:
                    # unreadable/old-format plans read as a miss, never an
                    # error — engine start would fall back to online warm-up
                    print(f"[MISS] {cfg.name}/{machine.name}: no readable "
                          f"v-current plan under {plan_store.root}")
                    continue
                stale = plan_staleness(plan, machine=machine,
                                       store=dispatch_store)
                if stale:
                    stale_count += 1
                    for fam, (rec, cur) in sorted(stale.items()):
                        print(f"[STALE] {cfg.name}/{machine.name} {fam}: "
                              f"plan={rec or 'none'} host={cur or 'none'}")
                else:
                    print(f"[FRESH] {cfg.name}/{machine.name}: "
                          f"{len(plan.entries)} entries, digests match")
        if stale_count:
            print(f"{stale_count} stale plan(s); rebuild with "
                  f"scripts/plan_artifacts.py", file=sys.stderr)
            return 1 if args.strict else 0
        return 0

    # one cache per machine sweep: tree/table memos amortize across configs;
    # resolution prefers the dispatch tables under --out when they exist
    plan_store = PlanStore(args.out)
    failures = 0
    for machine in machines:
        cache = DispatchCache(store=ArtifactStore(args.out))
        for cfg in cfgs:
            t0 = time.perf_counter()
            plan, dropped = build_serve_plan(cfg, machine=machine,
                                             cache=cache, **trace_kw)
            if not plan.entries:
                print(f"[FAIL] {cfg.name}/{machine.name}: every traced "
                      f"triple is infeasible", file=sys.stderr)
                failures += 1
                continue
            path = plan_store.save_plan(plan)
            sources = collections.Counter(e.rank_source
                                          for e in plan.entries)
            line = (f"[OK] {cfg.name}/{machine.name}: "
                    f"{len(plan.entries)} entries "
                    f"({', '.join(f'{s}={n}' for s, n in sorted(sources.items()))}) "
                    f"digest={plan.digest()} "
                    f"({time.perf_counter() - t0:.1f}s)\n"
                    f"     -> {path}")
            if dropped:
                line += ("\n     dropped (infeasible at shape): "
                         + ", ".join(op.label for op in dropped))
            print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
