"""MoE layer: routing, capacity, load-balance loss, top-1 exactness."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import capacity, init_moe, moe_block


def _cfg(E=4, k=2, d=32, f=64, cf=2.0):
    return ModelConfig(
        name="moe-test", layers=1, d_model=d, heads=4, kv_heads=2,
        d_ff=f, vocab=64, block="attn_moe",
        moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=f,
                      capacity_factor=cf))


def test_capacity_formula():
    assert capacity(1024, 384, 8, 1.25) == max(4, -(-1024 * 8 * 1.25 * 1 // 384))
    assert capacity(16, 4, 1, 1.0) == 4


def test_moe_output_finite_and_shaped():
    cfg = _cfg()
    p, axes = init_moe(jax.random.PRNGKey(0), cfg)
    assert axes["wi"] == ("expert", "embed", "ff")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert 0.0 < float(aux) < cfg.moe.num_experts * 2.0


def test_moe_top1_equals_dense_reference():
    """With top-1 routing and ample capacity the dispatch/combine machinery
    must reproduce a direct per-token expert evaluation exactly."""
    cfg = _cfg(E=4, k=1, cf=8.0)
    p, _ = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    y, _ = moe_block(p, x, cfg, group_size=32)

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    idx = jnp.argmax(logits, -1)                      # (1,32)
    ref = np.zeros_like(np.asarray(x))
    for t in range(32):
        e = int(idx[0, t])
        h = np.asarray(x[0, t]) @ np.asarray(p["wi"][e])
        g = np.asarray(x[0, t]) @ np.asarray(p["wg"][e])
        h = (g / (1 + np.exp(-g))) * h               # silu(g)*h
        ref[0, t] = h @ np.asarray(p["wo"][e])
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens are dropped => smaller output
    norm, but still finite (production overflow behaviour)."""
    cfg_lo = _cfg(E=4, k=2, cf=0.26)
    cfg_hi = _cfg(E=4, k=2, cf=8.0)
    p, _ = init_moe(jax.random.PRNGKey(4), _cfg())
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 32))
    y_lo, _ = moe_block(p, x, cfg_lo, group_size=64)
    y_hi, _ = moe_block(p, x, cfg_hi, group_size=64)
    n_lo = float(jnp.linalg.norm(y_lo))
    n_hi = float(jnp.linalg.norm(y_hi))
    assert np.isfinite(n_lo) and np.isfinite(n_hi)
    assert n_lo < n_hi


def test_moe_grouping_invariance():
    """Group size is an implementation knob: results must not depend on it
    when capacity is ample."""
    cfg = _cfg(E=4, k=2, cf=8.0)
    p, _ = init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 64, 32))
    y1, _ = moe_block(p, x, cfg, group_size=32)
    y2, _ = moe_block(p, x, cfg, group_size=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_gradients_flow_to_all_parts():
    cfg = _cfg()
    p, _ = init_moe(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 32))

    def loss(p):
        y, aux = moe_block(p, x, cfg)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "wi", "wg", "wo"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
