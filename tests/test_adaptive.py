"""Drift-injection harness for the adaptive serving loop (ISSUE 8).

The tentpole's verification subsystem.  Every test fabricates a workload
where measured reality disagrees with the frozen kernel pick — via the
deterministic ``SkewedTimer`` fixture (``conftest.py``), never a real
clock — and proves the three acceptance properties:

(a) **bounded detection** — a fabricated wrong frozen pick is detected and
    hot-swapped within exactly ``window x patience`` probes;
(b) **token-exact swap** — an engine that hot-swaps mid-traffic emits
    token streams identical to an unmonitored reference engine (the PR 7
    parity idiom: same prompts, compare ``Request.out``);
(c) **feasibility is inviolable** — no timing sequence (hypothesis) can
    ever swap *in* a candidate the constraint system proves infeasible.

Plus the guard rails: agreement never swaps, a noisy (non-consecutive)
disagreement never swaps, and a concurrent ``unfreeze`` beats a swap
publish (the freeze-generation race).

Determinism: all randomness flows through the seeded ``rng``/timer
fixtures (see ``tests/conftest.py``); safe under test-order shuffling.
"""
import numpy as np
import pytest

from conftest import SkewedTimer
from repro.artifacts import DispatchCache
from repro.artifacts.dispatch import set_default_cache
from repro.core import TPU_V5E
from repro.core.select import Candidate, rank_candidates
from repro.kernels.ops import FAMILIES
from repro.runtime.monitor import KernelMonitor, cand_key

MATMUL = FAMILIES["matmul"]
DATA = {"M": 256, "N": 256, "K": 256}

SLOW, MID, FAST = 8e-3, 4e-3, 1e-3


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


def _freeze_wrong_pick(cache):
    """Fabricate the drift scenario: freeze a non-best candidate as the
    incumbent and return (incumbent, true_best) — 'wrong' by measurement,
    which the skewed timer will make manifest."""
    ranked = rank_candidates(MATMUL, TPU_V5E, DATA)
    incumbent, best = ranked[1], ranked[0]
    cache.freeze_resolved([(MATMUL, TPU_V5E, DATA, incumbent, "symbolic")])
    return incumbent, best


def _monitor(cache, timer, **kw):
    defaults = dict(machine=TPU_V5E, window=2, patience=2, probe_every=1,
                    top_k=2, seed=0)
    defaults.update(kw)
    mon = KernelMonitor(cache, timer=timer, **defaults)
    mon.track(MATMUL, DATA)
    return mon


# ---------------------------------------------------------------------------
# (a) bounded detection + the swap itself
# ---------------------------------------------------------------------------

def test_wrong_pick_detected_and_swapped_within_bound(skewed_timer):
    cache = DispatchCache()
    incumbent, best = _freeze_wrong_pick(cache)
    skewed_timer.default = MID
    skewed_timer.skews[cand_key(incumbent)] = SLOW
    skewed_timer.skews[cand_key(best)] = FAST
    mon = _monitor(cache, skewed_timer)

    # probe_every=1 and one tracked triple: tick t runs probe t.  The
    # detection bound is window x patience probes — not one more.
    bound = mon.window * mon.patience
    for t in range(bound):
        assert mon.stats.swaps == 0
        mon.on_tick(t)
    assert mon.stats.swaps == 1
    assert mon.stats.windows == mon.patience
    assert mon.stats.disagreements == mon.patience

    ent = cache.frozen_entry("matmul", TPU_V5E.name, DATA)
    assert cand_key(ent.candidate) == cand_key(best)
    assert ent.source == "measured"               # live measurement decided
    (ev,) = mon.events
    assert ev.old == cand_key(incumbent) and ev.new == cand_key(best)
    assert ev.challenger_us < ev.incumbent_us
    assert ev.family == "matmul" and ev.tick == bound - 1
    assert "->" in ev.describe()


def test_agreement_never_swaps(skewed_timer):
    """Measurement confirming the frozen pick leaves it alone forever."""
    cache = DispatchCache()
    incumbent, best = _freeze_wrong_pick(cache)
    skewed_timer.default = MID
    skewed_timer.skews[cand_key(incumbent)] = FAST   # incumbent really is best
    mon = _monitor(cache, skewed_timer)
    for t in range(8 * mon.window * mon.patience):
        mon.on_tick(t)
    assert mon.stats.windows > 2 * mon.patience      # plenty of decisions
    assert mon.stats.disagreements == 0
    assert mon.stats.swaps == 0 and not mon.events
    ent = cache.frozen_entry("matmul", TPU_V5E.name, DATA)
    assert cand_key(ent.candidate) == cand_key(incumbent)


def test_nonconsecutive_disagreement_resets_streak(skewed_timer):
    """patience counts CONSECUTIVE disagreeing windows: one agreeing
    window in between resets the streak, so alternating windows never
    swap."""
    cache = DispatchCache()
    incumbent, best = _freeze_wrong_pick(cache)
    skewed_timer.default = MID
    mon = _monitor(cache, skewed_timer, patience=2)
    ik, bk = cand_key(incumbent), cand_key(best)
    for w in range(6):                               # alternate per window
        fast_now = SLOW if w % 2 == 0 else FAST
        skewed_timer.skews[ik] = fast_now
        skewed_timer.skews[bk] = FAST if w % 2 == 0 else SLOW
        # fresh reservoirs each window would be cheating: drown history
        # instead, the way real drift would
        for st in mon._triples.values():
            st.reservoirs.clear()
        for t in range(mon.window):
            mon.on_tick(w * mon.window + t)
    assert mon.stats.disagreements >= 2              # drift windows did land
    assert mon.stats.swaps == 0                      # but never consecutively


def test_probe_failure_is_data_not_error():
    """A timer that raises (kernel crash, transient OS noise) is counted
    and otherwise ignored — the frozen path keeps serving."""
    cache = DispatchCache()
    incumbent, _ = _freeze_wrong_pick(cache)

    def exploding_timer(family, plan, assignment, data, cfg):
        raise RuntimeError("boom")

    mon = _monitor(cache, exploding_timer)
    for t in range(4 * mon.window):
        mon.on_tick(t)
    assert mon.stats.probe_failures > 0
    assert mon.stats.samples == 0 and mon.stats.swaps == 0
    ent = cache.frozen_entry("matmul", TPU_V5E.name, DATA)
    assert cand_key(ent.candidate) == cand_key(incumbent)


def test_untracked_or_unfrozen_triples_are_noops(skewed_timer):
    """No tracked triples, or a tracked triple that is not frozen: on_tick
    must do nothing (the monitor guards the frozen lane only)."""
    mon = KernelMonitor(DispatchCache(), timer=skewed_timer)
    mon.on_tick(0)
    assert mon.stats.probes == 0
    cache = DispatchCache()                          # nothing frozen
    mon2 = _monitor(cache, skewed_timer)
    for t in range(4):
        mon2.on_tick(t)
    assert mon2.stats.probes == 0 and mon2.stats.swaps == 0


# ---------------------------------------------------------------------------
# freeze-generation race: a concurrent unfreeze beats the publish
# ---------------------------------------------------------------------------

class _RacingCache(DispatchCache):
    """Deterministic race: an unfreeze lands exactly between the monitor's
    generation capture and its publish."""

    @property
    def unfreeze_generation(self):
        gen = DispatchCache.unfreeze_generation.fget(self)
        self.unfreeze()                              # the concurrent drop
        return gen


def test_concurrent_unfreeze_blocks_swap(skewed_timer):
    cache = _RacingCache()
    incumbent, best = _freeze_wrong_pick(cache)
    skewed_timer.default = MID
    skewed_timer.skews[cand_key(incumbent)] = SLOW
    skewed_timer.skews[cand_key(best)] = FAST
    mon = _monitor(cache, skewed_timer)
    for t in range(mon.window * mon.patience):
        mon.on_tick(t)
    assert mon.stats.swap_blocked_gen == 1
    assert mon.stats.swaps == 0 and not mon.events
    assert cache.frozen_plan is None                 # the explicit drop won


# ---------------------------------------------------------------------------
# (c) hypothesis: no counter sequence swaps in an infeasible candidate
# ---------------------------------------------------------------------------

try:                                 # container may lack hypothesis: the
    from hypothesis import HealthCheck, given, settings  # noqa: E402
    from hypothesis import strategies as st              # noqa: E402
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:          # deterministic sweep drives the same
    HAVE_HYPOTHESIS = False          # property body below


def _bogus_candidate(base):
    """Looks like a stellar candidate (absurd score, real plan/leaf) but
    its assignment violates the constraint system: bm blown past every
    block/memory bound."""
    return Candidate(leaf_index=base.leaf_index, plan=base.plan,
                     assignment={**base.assignment, "bm": 1 << 20},
                     score=999.0)


def _check_no_infeasible_swap(timings):
    """The property: an adversarial ranker nominates an infeasible
    candidate, an adversarial timer feeds it arbitrary timings — whatever
    the sequence measures, the constraint re-proof must block the swap,
    and when the counters DID nominate it, the block must be
    observable."""
    cache = DispatchCache()
    ranked = rank_candidates(MATMUL, TPU_V5E, DATA)
    incumbent, bogus = ranked[0], _bogus_candidate(ranked[0])
    cache.freeze_resolved([(MATMUL, TPU_V5E, DATA, incumbent, "symbolic")])

    calls = {"n": 0}

    def seq_timer(family, plan, assignment, data, cfg):
        t = timings[calls["n"] % len(timings)]
        calls["n"] += 1
        return [t]

    mon = KernelMonitor(cache, machine=TPU_V5E, window=1, patience=1,
                        probe_every=1, top_k=2, timer=seq_timer,
                        ranker=lambda *a: [incumbent, bogus], seed=0)
    assert mon._infeasible(MATMUL, DATA, bogus)      # the scenario is real
    mon.track(MATMUL, DATA)
    for t in range(2 * len(timings)):
        mon.on_tick(t)

    ent = cache.frozen_entry("matmul", TPU_V5E.name, DATA)
    assert cand_key(ent.candidate) != cand_key(bogus)   # THE property
    assert cand_key(ent.candidate) == cand_key(incumbent)
    assert mon.stats.swaps == 0
    # every nomination was blocked-and-counted, and the bogus candidate is
    # evicted from the pool on first nomination (never re-tried forever)
    if mon.stats.swap_blocked_infeasible:
        assert mon.stats.swap_blocked_infeasible == 1
        key = ("matmul", tuple(sorted(DATA.items())))
        pool_keys = [cand_key(c) for c in mon._triples[key].pool]
        assert cand_key(bogus) not in pool_keys
    return mon.stats.swap_blocked_infeasible


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(timings=st.lists(
        st.floats(min_value=1e-6, max_value=1e-1,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=24))
    def test_no_timing_sequence_swaps_in_infeasible_candidate(timings):
        _check_no_infeasible_swap(timings)
else:
    @pytest.mark.parametrize("case", range(12))
    def test_no_timing_sequence_swaps_in_infeasible_candidate(case):
        """hypothesis-free fallback: hand-picked adversarial extremes plus
        a seeded sweep (TEST_SEED + case) over random timing sequences —
        the same property body the hypothesis version drives."""
        from conftest import TEST_SEED
        if case == 0:
            seq = [1e-6]                 # bogus always measures instant
        elif case == 1:
            seq = [1e-1]                 # everything identical and slow
        elif case == 2:
            seq = [1e-1, 1e-6] * 6       # incumbent slow / bogus fast
        else:
            g = np.random.default_rng(TEST_SEED + case)
            seq = list(g.uniform(1e-6, 1e-1, int(g.integers(1, 24))))
        blocked = _check_no_infeasible_swap(seq)
        if case == 2:                    # the crafted nomination must land
            assert blocked == 1


# ---------------------------------------------------------------------------
# (b) engine-level: the hot-swap is token-exact
# ---------------------------------------------------------------------------

def test_engine_hot_swap_is_token_exact(rng):
    """An engine whose monitor hot-swaps a kernel pick mid-traffic emits
    exactly the token streams of an unmonitored reference engine — the
    swap changes *which variant dispatches*, never *what it computes*
    (PR 7 parity idiom: same prompts, compare Request.out).  The swap
    must also land in an installed flight recorder with a matching tick
    id (ISSUE 10 provenance-completeness)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.plans.trace import trace_warm_set
    from repro.runtime import ServeEngine

    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab, int(n)) for n in (12, 20, 7)]

    def serve(monitored):
        cache = DispatchCache()
        set_default_cache(cache)
        eng = ServeEngine(cfg, params, max_batch=4, max_len=128,
                          page_size=16, warm_kernels=True, plan_store=False,
                          monitor=monitored, monitor_window=1,
                          monitor_every=1, swap_patience=1,
                          monitor_timer=SkewedTimer(default=MID))
        if monitored:
            # narrow the monitor to ONE matmul triple and skew its frozen
            # incumbent slow, so the swap deterministically fires mid-run
            op = next(o for o in trace_warm_set(cfg, max_len=128,
                                                page_size=16)
                      if o.family == "matmul")
            mon = KernelMonitor(cache, machine=TPU_V5E, window=1,
                                patience=1, probe_every=1, top_k=2,
                                timer=eng.monitor.timer, seed=0)
            mon.track(FAMILIES["matmul"], op.data_dict())
            ent = cache.frozen_entry("matmul", TPU_V5E.name, op.data_dict())
            mon.timer.skews[cand_key(ent.candidate)] = SLOW
            eng.monitor = mon
        for p in prompts:
            eng.submit(p, max_new=8)
        done = eng.run_until_drained()
        return eng, {r.rid: list(r.out) for r in done}

    from repro.obs import tracing

    ref_eng, ref_out = serve(monitored=False)
    with tracing(capacity=1 << 14) as rec:
        mon_eng, mon_out = serve(monitored=True)
    assert mon_eng.monitor.stats.swaps >= 1          # the swap really fired
    assert mon_eng.monitor.events
    assert mon_out == ref_out                        # token-exact across it
    assert ref_eng.monitor is None
    # provenance-completeness: every SwapEvent appears in the trace, in
    # order, stamped with the tick the monitor swapped on
    traced = [(r["family"], r["tick"]) for r in rec.records()
              if r["etype"] == "swap"]
    assert traced == [(e.family, e.tick) for e in mon_eng.monitor.events]
