"""Warm-path fast lane: instantiation-cache identity + frozen dispatch plans.

Acceptance properties (ISSUE 4):

- ``instantiate`` returns an *identical callable object* across repeated
  resolutions of the same triple — the property that stabilizes jit keys;
- keying is exact: same assignment with ``interpret=True`` vs ``False`` and
  differing plan flags (``vmem_cache``) yield *distinct* cached callables;
- frozen parity: with and without ``freeze()``, every family resolves the
  same candidate for every warm-up triple;
- ``get_default_cache`` picks up an artifact dir that appears *after* the
  first cold dispatch (store snapshotting regression).
"""
import pytest

from repro.artifacts import ArtifactStore, DispatchCache, compile_family
from repro.artifacts.dispatch import get_default_cache, set_default_cache
from repro.core import TPU_V5E, best_variant
from repro.core.select import STATS
from repro.kernels.ops import FAMILIES

#: One serving-representative triple per family (mirrors benchmarks).
SHAPES = {
    "matmul": {"M": 512, "N": 512, "K": 512},
    "matadd": {"M": 512, "N": 512},
    "jacobi1d": {"N": 2048},
    "transpose": {"M": 512, "N": 512},
    "flash_attention": {"SQ": 256, "HD": 64},
    "ssd_scan": {"SQ": 256, "HD": 64, "STATE": 64},
}


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


# ---------------------------------------------------------------------------
# Instantiation cache: identity + keying
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname", sorted(SHAPES), ids=str)
def test_instantiate_identity_across_resolutions(fname):
    """Repeated resolutions of the same triple return the SAME object."""
    fam, data = FAMILIES[fname], SHAPES[fname]
    cache = DispatchCache()
    c1 = cache.best_variant(fam, TPU_V5E, data)
    c2 = cache.best_variant(fam, TPU_V5E, data)
    assert c1 == c2
    f1 = fam.instantiate(c1.plan, c1.assignment, interpret=True,
                         leaf_index=c1.leaf_index)
    f2 = fam.instantiate(c2.plan, c2.assignment, interpret=True,
                         leaf_index=c2.leaf_index)
    assert f1 is f2


def test_instantiate_key_interpret_mode():
    fam, data = FAMILIES["matmul"], SHAPES["matmul"]
    cand = best_variant(fam, TPU_V5E, data, use_cache=False)
    fi = fam.instantiate(cand.plan, cand.assignment, interpret=True)
    fc = fam.instantiate(cand.plan, cand.assignment, interpret=False)
    assert fi is not fc
    assert fam.instantiate(cand.plan, cand.assignment, interpret=True) is fi


def test_instantiate_key_plan_flags():
    """Same assignment under different plan flags => distinct callables."""
    fam = FAMILIES["matmul"]
    cand = best_variant(fam, TPU_V5E, SHAPES["matmul"], use_cache=False)
    plan = cand.plan
    assert plan.flags.get("vmem_cache", True)
    uncached_plan = plan.with_flag("vmem_cache", False)
    f_cached = fam.instantiate(plan, cand.assignment, interpret=True)
    f_uncached = fam.instantiate(uncached_plan, cand.assignment,
                                 interpret=True)
    assert f_cached is not f_uncached


def test_instantiate_zero_rebuilds_when_warm():
    """Steady-state op calls never invoke the kernel builder again."""
    fam, data = FAMILIES["matadd"], SHAPES["matadd"]
    cache = DispatchCache()
    cand = cache.best_variant(fam, TPU_V5E, data)
    fam.instantiate(cand.plan, cand.assignment, interpret=True,
                    leaf_index=cand.leaf_index)          # build once
    misses_before = fam.instantiation_cache.misses
    for _ in range(50):
        c = cache.best_variant(fam, TPU_V5E, data)
        fam.instantiate(c.plan, c.assignment, interpret=True,
                        leaf_index=c.leaf_index)
    assert fam.instantiation_cache.misses == misses_before


def test_instantiate_fresh_bypasses_cache():
    fam = FAMILIES["transpose"]
    cand = best_variant(fam, TPU_V5E, SHAPES["transpose"], use_cache=False)
    a = fam.instantiate_fresh(cand.plan, cand.assignment, True)
    b = fam.instantiate_fresh(cand.plan, cand.assignment, True)
    assert a is not b                     # the pre-fast-lane behaviour


# ---------------------------------------------------------------------------
# Frozen dispatch plans
# ---------------------------------------------------------------------------

def _freeze_all(cache):
    return cache.freeze([(FAMILIES[f], TPU_V5E, d)
                         for f, d in SHAPES.items()])


def test_frozen_parity_all_families():
    """Acceptance: freeze() changes the cost of a lookup, never its answer."""
    frozen_cache = DispatchCache()
    plain_cache = DispatchCache()
    _freeze_all(frozen_cache)
    for fname, data in SHAPES.items():
        fam = FAMILIES[fname]
        via_frozen = frozen_cache.best_variant(fam, TPU_V5E, data)
        via_tiers = plain_cache.best_variant(fam, TPU_V5E, data)
        cold = best_variant(fam, TPU_V5E, data, use_cache=False)
        assert via_frozen == via_tiers == cold
        # the observability lookup sees the same snapshot (and counts)
        ent = frozen_cache.frozen_entry(fam.name, TPU_V5E.name, data)
        assert ent is not None and ent.candidate == via_frozen
        assert ent.source in ("measured", "symbolic", "cold")
    assert frozen_cache.stats.frozen_hits == 2 * len(SHAPES)
    assert frozen_cache.frozen_entry("matmul", TPU_V5E.name,
                                     {"M": 7, "N": 7, "K": 7}) is None


def test_frozen_resolution_skips_lru_and_enumeration():
    cache = DispatchCache()
    _freeze_all(cache)
    STATS.reset()
    before = cache.stats.memory_hits
    for fname, data in SHAPES.items():
        cache.best_variant(FAMILIES[fname], TPU_V5E, data)
    assert STATS.enumerate_calls == 0            # no tree search
    assert cache.stats.memory_hits == before     # not even the LRU
    assert cache.stats.frozen_hits >= len(SHAPES)


def test_warm_callable_identity_and_parity():
    """The ops-layer fast lane returns the frozen, memoized callable."""
    cache = DispatchCache()
    plan = _freeze_all(cache)
    for fname, data in SHAPES.items():
        fam = FAMILIES[fname]
        items = tuple(data.items())
        f1 = cache.warm_callable(fam, TPU_V5E, items, True)
        f2 = cache.warm_callable(fam, TPU_V5E, items, True)
        assert f1 is f2
        ent = plan.get(fam.name, TPU_V5E.name, data)
        assert ent is not None and f1 is ent.fns[1]
        # and identical to what a direct memoized instantiate returns
        cand = ent.candidate
        assert f1 is fam.instantiate(cand.plan, cand.assignment,
                                     interpret=True,
                                     leaf_index=cand.leaf_index)


def test_warm_callable_item_order_insensitive():
    cache = DispatchCache()
    _freeze_all(cache)
    data = SHAPES["matmul"]
    fam = FAMILIES["matmul"]
    fwd = cache.warm_callable(fam, TPU_V5E, tuple(data.items()), False)
    rev = cache.warm_callable(fam, TPU_V5E,
                              tuple(reversed(list(data.items()))), False)
    assert fwd is rev


def test_warm_callable_miss_falls_back_to_tiers():
    """An unfrozen triple still resolves (cache-miss-never-error) and the
    returned callable is the memoized one (stable identity on repeat)."""
    cache = DispatchCache()
    _freeze_all(cache)
    items = (("M", 384), ("N", 384), ("K", 384))   # never frozen
    f1 = cache.warm_callable(FAMILIES["matmul"], TPU_V5E, items, True)
    f2 = cache.warm_callable(FAMILIES["matmul"], TPU_V5E, items, True)
    assert f1 is f2
    assert cache.stats.memory_hits >= 1            # served by the LRU tier


def test_late_store_attach_refreezes_stale_cold_snapshots(tmp_path):
    """A frozen plan must not pin pre-artifact cold picks forever: attaching
    a store re-freezes the plan's own warm-up triples against the new
    tables (same candidate by parity, fresh source), and an explicit
    re-freeze also resolves through the tiers, never the old plan."""
    fam, data = FAMILIES["matmul"], SHAPES["matmul"]
    cache = DispatchCache()
    cache.freeze([(fam, TPU_V5E, data)])
    assert cache.frozen_plan.get(fam.name, TPU_V5E.name,
                                 data).source == "cold"
    store = ArtifactStore(tmp_path)
    compile_family(fam, store, machines=[TPU_V5E], shapes=[dict(data)])
    cache.attach_store(store)                    # tables appear later
    ent = cache.frozen_plan.get(fam.name, TPU_V5E.name, data)
    assert ent.source == "symbolic"              # auto-refrozen, not pinned
    assert ent.candidate == best_variant(fam, TPU_V5E, data,
                                         use_cache=False)
    # explicit re-freeze equally re-reads the tables (never the old plan)
    cache.freeze([(fam, TPU_V5E, data)])
    assert cache.frozen_plan.get(fam.name, TPU_V5E.name,
                                 data).source == "symbolic"


def test_unfreeze_wins_over_inflight_refreeze():
    """The generation guard: a freeze carrying a stale unfreeze generation
    (attach_store's re-freeze racing an explicit unfreeze) must not
    resurrect the dropped plan."""
    fam, data = FAMILIES["matmul"], SHAPES["matmul"]
    cache = DispatchCache()
    plan = cache.freeze([(fam, TPU_V5E, data)])
    stale_gen = cache._unfreeze_gen
    cache.unfreeze()                             # explicit drop
    out = cache.freeze(plan.triples, _expect_unfreeze_gen=stale_gen)
    assert cache.frozen_plan is None and out is None
    # a current-generation freeze still publishes
    cache.freeze(plan.triples)
    assert cache.frozen_plan is not None


def test_freeze_is_monotonic_and_unfreeze_drops():
    cache = DispatchCache()
    cache.freeze([(FAMILIES["matmul"], TPU_V5E, SHAPES["matmul"])])
    cache.freeze([(FAMILIES["matadd"], TPU_V5E, SHAPES["matadd"])])
    plan = cache.frozen_plan
    assert len(plan) == 2                          # merged, not replaced
    assert plan.get("matmul", TPU_V5E.name, SHAPES["matmul"]) is not None
    cache.unfreeze()
    assert cache.frozen_plan is None
    # tiers still serve after unfreeze
    assert cache.best_variant(FAMILIES["matmul"], TPU_V5E,
                              SHAPES["matmul"]) is not None


def test_ops_warm_path_zero_rebuilds():
    """End to end through the public op: repeated calls build nothing."""
    import jax
    import numpy as np
    from repro.kernels import ops, ref
    from repro.runtime.serving import warm_kernel_dispatch  # noqa: F401
    fam = FAMILIES["matmul"]
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    out = ops.matmul(a, b, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                               rtol=1e-4, atol=1e-3)
    misses_before = fam.instantiation_cache.misses
    enumerate_before = STATS.enumerate_calls
    for _ in range(5):
        ops.matmul(a, b, impl="pallas", interpret=True)
    assert fam.instantiation_cache.misses == misses_before
    assert STATS.enumerate_calls == enumerate_before


def test_serving_warmup_feeds_frozen_plan():
    """warm_kernel_dispatch(freeze=True) populates the process cache's
    frozen plan with every reported pick, at parity with the picks."""
    from repro.configs import get_smoke_config
    from repro.plans import op_label
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("llama3_8b")
    picks = warm_kernel_dispatch(cfg, max_len=128)
    cache = get_default_cache()
    plan = cache.frozen_plan
    assert plan is not None and len(plan) == len(picks)
    hd = cfg.hd
    data = {"SQ": 128, "HD": hd}
    ent = plan.get("flash_attention", TPU_V5E.name, data)
    assert ent is not None
    label = op_label("flash_attention", data)
    assert ent.candidate == picks[label]["candidate"]
    # freeze=False leaves the plan untouched
    set_default_cache(DispatchCache())
    warm_kernel_dispatch(cfg, max_len=128, freeze=False)
    assert get_default_cache().frozen_plan is None


# ---------------------------------------------------------------------------
# get_default_cache store snapshotting (satellite regression)
# ---------------------------------------------------------------------------

def test_default_cache_attaches_store_appearing_later(tmp_path, monkeypatch):
    """An artifact dir compiled AFTER the first cold dispatch must be seen:
    the auto-created default re-probes while store-less and serves tier-2
    hits once tables exist."""
    art = tmp_path / "artifacts"
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(art))
    set_default_cache(None)                       # re-arm the env probe
    fam, data = FAMILIES["matmul"], SHAPES["matmul"]
    cache = get_default_cache()
    assert cache.store is None                    # dir does not exist yet
    cache.best_variant(fam, TPU_V5E, data)        # first dispatch: cold
    assert cache.stats.cold_builds == 1

    compile_family(fam, ArtifactStore(art), machines=[TPU_V5E],
                   shapes=[{"M": 1024, "N": 1024, "K": 1024}, dict(data)])
    # a NEW shape (LRU miss) must now come from the disk artifact
    cand = get_default_cache().best_variant(fam, TPU_V5E,
                                            {"M": 1024, "N": 1024, "K": 1024})
    assert cache.stats.disk_hits == 1
    assert cache.store is not None
    assert cand == best_variant(fam, TPU_V5E,
                                {"M": 1024, "N": 1024, "K": 1024},
                                use_cache=False)
    # ... and the attach unpinned the pre-store LRU entry: the ORIGINAL
    # shape re-resolves against the table instead of replaying its cold
    # answer forever
    again = get_default_cache().best_variant(fam, TPU_V5E, data)
    assert cache.stats.disk_hits == 2
    assert again == best_variant(fam, TPU_V5E, data, use_cache=False)


def test_explicit_cache_store_is_never_overridden(tmp_path, monkeypatch):
    """A cache installed via set_default_cache keeps its (lack of) store
    even when an artifact dir exists — test isolation depends on it."""
    art = tmp_path / "artifacts"
    fam = FAMILIES["matmul"]
    compile_family(fam, ArtifactStore(art), machines=[TPU_V5E],
                   shapes=[SHAPES["matmul"]])
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(art))
    mine = DispatchCache()
    set_default_cache(mine)
    got = get_default_cache()
    got.best_variant(fam, TPU_V5E, SHAPES["matmul"])
    assert got is mine and got.store is None
    assert got.stats.cold_builds == 1             # not a disk hit
