"""Per-arch smoke tests (reduced same-family configs) + layer equivalences."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          prefill)
from repro.models import layers as L
from repro.models.config import SHAPES_BY_NAME

KEY = jax.random.PRNGKey(0)


def _extras(cfg, B, seed=0):
    kw = {}
    if cfg.encoder is not None:
        kw["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed), (B, cfg.encoder.seq_len, cfg.d_model))
    elif cfg.frontend == "stub":
        kw["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed), (B, 8, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params, axes = init_model(KEY, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits, aux = jax.jit(
        lambda p, t: forward(p, cfg, t, **_extras(cfg, B)))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    from repro.optim import adamw, constant
    from repro.runtime import build_train_step
    cfg = get_smoke_config(arch)
    params, _ = init_model(KEY, cfg)
    opt = adamw(constant(1e-3))
    state = opt.init(params)
    step = jax.jit(build_train_step(cfg, opt, microbatches=2))
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        **_extras(cfg, B),
    }
    params2, state2, metrics = step(params, state, batch,
                                    jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, params2))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Greedy continuation via (prefill -> decode_step) must equal running
    the full forward over the extended sequence — KV/SSM cache correctness.

    For MoE archs the capacity factor is raised so routing is dropless:
    capacity dropping makes train-forward and decode legitimately differ
    (dropping depends on which other tokens share the batch)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    kw = _extras(cfg, B)

    cache = init_cache(cfg, B, max_len=S + 4)
    last, cache = prefill(params, cfg, tokens, cache, **kw)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)[:, None]

    # reference: full forward over S+1 tokens
    ext = jnp.concatenate([tokens, nxt], axis=1)
    ref_logits, _ = forward(params, cfg, ext, **kw)
    dec_logits, cache = decode_step(params, cfg, nxt, cache,
                                    jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ref_logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_param_count_analytic_close():
    """ModelConfig.param_count() within 10% of the real initialized count."""
    from repro.models import param_count
    for arch in ("yi_6b", "llama3_8b", "mamba2_130m"):
        cfg = get_smoke_config(arch)
        params, _ = init_model(KEY, cfg)
        actual = param_count(params)
        claimed = cfg.param_count()
        assert abs(actual - claimed) / actual < 0.10, (arch, actual, claimed)


def test_full_config_param_counts_match_papers():
    """Full configs must land near their published sizes."""
    expect = {
        "llama3_8b": (8.0e9, 0.15),
        "yi_6b": (6.1e9, 0.15),
        "qwen1p5_4b": (4.0e9, 0.25),
        "kimi_k2_1t_a32b": (1.0e12, 0.2),
        "chameleon_34b": (34e9, 0.15),
        "mamba2_130m": (130e6, 0.3),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_chunked_sdpa_equals_dense():
    B, S, nh, nk, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(KEY, (B, S, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, nk, hd))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, nk, hd))
    pos = jnp.arange(S)
    for window in (None, 64):
        dense = L._sdpa(q, k, v, causal=True, window=window,
                        q_positions=pos, k_positions=pos)
        chunked = L._sdpa_chunked(q, k, v, causal=True, window=window,
                                  q_positions=pos, k_positions=pos,
                                  q_block=96)   # non-divisible on purpose
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_ring_cache_equals_full_cache_decode():
    """Windowed ring cache must produce the same logits as a full cache."""
    cfg = get_smoke_config("hymba_1p5b")          # window=32
    params, _ = init_model(jax.random.PRNGKey(5), cfg)
    B, S, extra = 1, 40, 6                        # S > window
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, cfg.vocab)

    # ring cache (max_len > window -> ring of size window)
    ring = init_cache(cfg, B, max_len=S + extra)
    assert ring["k"].shape[2] == cfg.window       # (L,B,W,nk,hd)
    last_r, ring = prefill(params, cfg, tokens, ring)

    # reference: full forward step-by-step
    cur = tokens
    for i in range(extra):
        nxt = jnp.argmax(last_r, -1).astype(jnp.int32)[:, None]
        full_logits, _ = forward(params, cfg,
                                 jnp.concatenate([cur, nxt], 1))
        dec_logits, ring = decode_step(params, cfg, nxt, ring,
                                       jnp.asarray(S + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits[:, -1], np.float32),
            rtol=3e-2, atol=3e-2)
        cur = jnp.concatenate([cur, nxt], 1)
        last_r = dec_logits


def test_vector_cache_index_matches_scalar():
    """Continuous-batching (vector index) decode == scalar-index decode."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(7), cfg)
    B, S = 3, 16
    tokens = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0, cfg.vocab)
    c1 = init_cache(cfg, B, 32)
    c2 = init_cache(cfg, B, 32)
    last, c1 = prefill(params, cfg, tokens, c1)
    _, c2 = prefill(params, cfg, tokens, c2)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    lg_s, _ = decode_step(params, cfg, nxt, c1, jnp.asarray(S, jnp.int32))
    lg_v, _ = decode_step(params, cfg, nxt, c2,
                          jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_v, np.float32),
                               np.asarray(lg_s, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_shapes_registry():
    assert set(SHAPES_BY_NAME) == {"train_4k", "prefill_32k", "decode_32k",
                                   "long_500k"}
    assert SHAPES_BY_NAME["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("flags", [("attn_q_heads",), ("rope_compute",),
                                   ("probs_bf16",),
                                   ("attn_q_heads", "rope_compute",
                                    "probs_bf16")])
def test_perf_flags_preserve_numerics(flags):
    """Beyond-paper perf variants must stay within bf16 noise of baseline."""
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    base, _ = forward(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, perf_flags=flags)
    out, _ = forward(params, cfg2, tokens)
    b = np.asarray(base, np.float32)
    o = np.asarray(out, np.float32)
    rel = np.abs(o - b).max() / (np.abs(b).max() + 1e-9)
    assert rel < 0.05, (flags, rel)
