"""Chunked paged prefill must be token-for-token equal to whole-prompt
prefill — chunks carry no padding, so the recurrent SSM state and MoE
routing see exactly the same tokens either way."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import (init_cache, init_model, init_paged_cache,
                          paged_prefill_chunk, prefill)
from repro.runtime import ServeEngine
from repro.runtime.kv_pool import GARBAGE_BLOCK


def _chunked_logits(cfg, params, prompt, chunks, page_size=8):
    """Drive the prompt through paged_prefill_chunk in the given pieces and
    return the final chunk's last-token logits."""
    assert sum(chunks) == len(prompt)
    nblk = -(-len(prompt) // page_size)
    cache = init_paged_cache(cfg, nblk + 1, page_size, batch=1)
    table = jnp.asarray(np.arange(1, nblk + 1, dtype=np.int32)[None])
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, start = None, 0
    for c in chunks:
        logits, cache = paged_prefill_chunk(
            params, cfg, toks[:, start:start + c], cache, jnp.int32(start),
            table, jnp.int32(0))
        start += c
    return logits[0]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_130m", "hymba_1p5b",
                                  "kimi_k2_1t_a32b"])
def test_chunked_prefill_matches_whole_prompt(arch):
    """dense / SSM / hybrid-window / MoE: the final chunk's greedy token
    equals whole-prompt prefill's, and — where the layer semantics admit
    it — so do the logits.

    The MoE config is greedy-token only: GShard capacity dropping is
    applied per routing call, so a whole 13-token group and an 8-token
    chunk legitimately drop *different* overflow tokens when an expert's
    capacity binds.  Token-for-token generation equality (the serving
    contract) is asserted; exact logits equality is not a property the
    capacity-dropping layer has across group sizes."""
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 13)      # 13 -> chunks 8+4+1
    want, _ = prefill(params, cfg, jnp.asarray(prompt[None], jnp.int32),
                      init_cache(cfg, 1, 32))
    want = want[0]
    got = _chunked_logits(cfg, params, prompt, [8, 4, 1])
    assert int(jnp.argmax(got)) == int(jnp.argmax(want)), arch
    if cfg.block != "attn_moe":
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_single_chunk_equals_many_chunks():
    """Chunk-boundary invariance: any decomposition yields the same logits
    (exact — the same ops run over the same tokens, only split)."""
    cfg = get_smoke_config("mamba2_130m")        # recurrent state threading
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    prompt = np.random.default_rng(1).integers(0, cfg.vocab, 12)
    one = _chunked_logits(cfg, params, prompt, [12])
    many = _chunked_logits(cfg, params, prompt, [4, 4, 2, 1, 1])
    np.testing.assert_allclose(np.asarray(many, np.float32),
                               np.asarray(one, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_engine_pool_no_leaks_across_churn():
    """Continuous batching over more requests than slots: every retirement
    returns its blocks; the drained pool is exactly full again."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=48, page_size=8,
                      prefill_chunk=8)
    rng = np.random.default_rng(2)
    for i in range(7):
        eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(3, 20))),
                   max_new=3)
    done = eng.run_until_drained()
    assert len(done) == 7
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0
    assert eng.pool.num_free == eng.pool.capacity
    # every block table the engine built stayed off the garbage block
    assert all(GARBAGE_BLOCK not in eng.pool._live for _ in range(1))


@pytest.mark.slow
def test_preemption_recompute_is_deterministic():
    """A pool too small for concurrent decode growth forces preemption;
    recompute (re-prefill of prompt + generated tokens) must reproduce the
    un-preempted outputs exactly (greedy decode is deterministic)."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    # equal-length prompts: both rows decode concurrently, and their joint
    # growth (2 x 19 tokens = 10 blocks) exceeds the tight pool's 8
    prompts = [rng.integers(0, cfg.vocab, 9) for _ in range(3)]

    def run(num_blocks):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=48, page_size=4,
                          prefill_chunk=8, num_blocks=num_blocks,
                          watermark_blocks=0)
        for p in prompts:
            eng.submit(p, max_new=10)
        done = {r.rid: r.out for r in eng.run_until_drained()}
        assert len(done) == 3
        return done, eng

    roomy, _ = run(None)                         # full-size pool: no pressure
    tight, eng = run(9)                          # 32-token pool
    assert eng.sched.stats.preemptions > 0       # pressure actually happened
    assert tight == roomy
    eng.pool.check_invariants()
