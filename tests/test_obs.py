"""Observability layer (ISSUE 10): flight recorder, event schema,
decision provenance, and the unified metrics registry.

Acceptance properties:

- the ring is bounded with monotonic seq ids and counted (never silent)
  drops; the frozen warm lane stays uncounted unless 1-in-N sampling is
  opted into;
- JSONL export is byte-deterministic (sorted keys, minimal separators,
  tick-index timestamps) and every record validates against
  ``EVENT_SCHEMA``;
- ``SwapEvent.describe`` and ``DegradeEvent.describe`` render through
  ONE pinned transition convention (satellite: the two logs cannot
  drift);
- over a seeded 500-cycle alloc/retire + preempt workload, the live
  ``PoolStats``/``SchedStats`` counters exactly equal an independently
  hand-tracked reference, and the trace reconstructs them;
- ``DispatchCache`` emits a provenance record per non-frozen resolution
  (tier source, candidate rank, demotion marks) and ``demote`` lands in
  the trace;
- ``ObsRegistry`` snapshots every surface and renders stable text.
"""
import dataclasses
import json
from collections import Counter

import numpy as np
import pytest

from repro.artifacts import DispatchCache
from repro.artifacts.dispatch import DegradeEvent
from repro.core import TPU_V5E
from repro.kernels.matmul import FAMILY as MATMUL
from repro.obs import (FlightRecorder, ObsRegistry, describe_transition,
                       get_recorder, install, tracing, validate_record)
from repro.obs.events import AdmissionDecision, DispatchDecision, TickSpan
from repro.runtime.kv_pool import PREFIX_ROOT, PagedKVPool
from repro.runtime.monitor import SwapEvent
from repro.runtime.scheduler import Request, Scheduler

MM_DATA = {"M": 64, "N": 64, "K": 64}


def _adm(i):
    return AdmissionDecision(tick=i, action="admit", rid=i, slot=0,
                             queue_depth=0)


# ---------------------------------------------------------------------------
# Flight recorder: ring bounds, sampling, determinism
# ---------------------------------------------------------------------------

def test_ring_bounds_counted_drops_and_monotonic_seq():
    rec = FlightRecorder(capacity=8)
    for i in range(20):
        rec.emit(_adm(i))
    assert rec.emitted == 20
    assert len(rec) == 8
    assert rec.dropped == 12                 # aged out, counted not silent
    seqs = [r["seq"] for r in rec.records()]
    assert seqs == list(range(12, 20))       # ids climb across drops
    for r in rec.records():
        validate_record(r)


def test_recorder_rejects_bad_knobs():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)
    with pytest.raises(ValueError):
        FlightRecorder(sample_frozen_every=-1)


def test_warm_lane_sampling_is_one_in_n():
    rec = FlightRecorder(sample_frozen_every=3)
    for _ in range(10):
        rec.sample_warm("matmul", "tpu_v5e", {"M": 8})
    recs = rec.records()
    assert len(recs) == 3                    # calls 3, 6, 9
    for r in recs:
        validate_record(r)
        assert r["surface"] == "warm_sampled"
        assert r["source"] == "frozen"
        assert r["family"] == "matmul"


def test_export_jsonl_is_byte_deterministic():
    def build():
        rec = FlightRecorder()
        rec.tick = 3
        rec.emit(DispatchDecision(
            tick=3, family="matmul", machine="tpu_v5e", data=(("M", 8),),
            bucket="b0", leaf=2, assignment=(("TX", 4),), source="measured",
            surface="resolve", rank=1, demoted=0))
        rec.emit(TickSpan(tick=3, admitted=1, prefill_tokens=8,
                          decode_rows=2, preempted=0, cancelled=0,
                          finished=1, duration_us=12.5))
        return rec.export_jsonl()

    a, b = build(), build()
    assert a == b and a.endswith("\n")
    for line in a.splitlines():
        rec = json.loads(line)
        validate_record(rec)
        assert list(rec) == sorted(rec)      # sorted keys on the wire
        assert ": " not in line and ", " not in line   # minimal separators


def test_tracing_context_restores_previous_recorder():
    outer = FlightRecorder()
    install(outer)
    try:
        with tracing() as inner:
            assert get_recorder() is inner
        assert get_recorder() is outer
    finally:
        install(None)


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------

def test_validate_record_rejects_malformed_records():
    good = {"seq": 0, "etype": "fault_fired", "tick": 1, "site": "s",
            "kind": "error", "arg": 0}
    validate_record(good)                    # sanity: the fixture is valid
    bads = [
        {**good, "etype": "nope"},                       # unknown etype
        {k: v for k, v in good.items() if k != "site"},  # missing field
        {**good, "arg": "zero"},                         # wrong type
        {**good, "extra": 1},                            # unknown field
        {**good, "seq": -1},                             # bad seq
        {"seq": 0, "etype": "admission_decision", "tick": 0,
         "action": "explode", "rid": 1, "slot": -1,
         "queue_depth": 0},                              # unknown action
    ]
    for bad in bads:
        with pytest.raises(ValueError):
            validate_record(bad)


# ---------------------------------------------------------------------------
# Satellite: one pinned rendering convention for swap + degrade logs
# ---------------------------------------------------------------------------

def test_swap_and_degrade_describe_share_pinned_format():
    old = (2, (("TX", 8),))
    new = (5, (("TX", 16),))
    swap = SwapEvent(tick=7, family="matmul", data=(("M", 512), ("N", 512)),
                     old=old, new=new, incumbent_us=12.0, challenger_us=3.5,
                     windows=2)
    assert swap.describe() == (
        "tick 7: swapped matmul@M=512,N=512 "
        "(('TX', 8),) (12.0us) -> (('TX', 16),) (3.5us) after 2 windows")
    ev = DegradeEvent(tick=9, family="matmul", machine="tpu_v5e",
                      data=(("M", 512),), old=old, new=new,
                      error="InjectedFault('serve.decode')",
                      source="measured")
    assert ev.describe() == (
        "tick 9: demoted matmul@M=512 "
        "(('TX', 8),) -> (('TX', 16),) (measured) "
        "after InjectedFault('serve.decode')")
    ex = dataclasses.replace(ev, exhausted=True)
    assert ex.describe() == ev.describe() + " [ladder exhausted; reset]"
    # both renderings come out of the one shared helper
    assert describe_transition(
        tick=1, verb="v", family="f", data=(("a", 2),), old="O", new="N",
        note="n", cause="c", tail="!") == "tick 1: v f@a=2 O -> N (n) after c!"


# ---------------------------------------------------------------------------
# Satellite: counters vs a hand-tracked reference (seeded 500 cycles)
# ---------------------------------------------------------------------------

def test_pool_counters_match_hand_tracked_reference(rng):
    """500 seeded alloc/register/retire cycles: ``peak_live`` and
    ``cache_evictions`` must equal a reference tracked from the pool's
    *structural* observables (free list + refcount table sizes), not its
    stats."""
    pool = PagedKVPool(17, 4)                # 16 allocatable blocks
    live, tok = [], 0
    expected_peak = expected_evictions = 0
    for _ in range(500):
        if rng.random() < 0.55 or not live:
            n = int(rng.integers(1, 4))
            free_before = pool.num_free
            reclaim_before = pool.num_reclaimable
            got = pool.alloc(n)
            if got is None:                  # refusal: genuinely short
                assert n > free_before + reclaim_before
                continue
            # alloc reclaims exactly the shortfall from the prefix cache
            expected_evictions += max(0, n - free_before)
            h = PREFIX_ROOT                  # pin each block in the index
            for b in got:
                h = pool.register_prefix(h, tuple(range(tok, tok + 4)), b)
                tok += 4
            live.append(got)
            expected_peak = max(expected_peak, pool.num_live)
        else:
            pool.free(live.pop(int(rng.integers(len(live)))))
    assert pool.stats.peak_live == expected_peak
    assert pool.stats.cache_evictions == expected_evictions
    assert expected_evictions > 0            # the mix really hit pressure
    pool.check_invariants(block_tables=live)


def test_sched_counters_match_hand_tracked_reference_and_trace(rng):
    """500 seeded scheduler ticks under pool pressure + a queue bound
    (the ``test_kv_pool._drive`` engine stand-in): ``admissions``/
    ``preemptions``/``shed`` must equal per-tick hand counts, and the
    emitted ``admission_decision`` stream must reconstruct all of them
    (the action <-> counter mapping is 1:1)."""
    pool = PagedKVPool(7, 4)                 # 6 blocks: decode growth preempts
    sched = Scheduler(pool, max_batch=2, max_len=24, prefill_chunk=8,
                      watermark_blocks=0, max_queue=3)
    admitted_ref = preempt_ref = shed_ref = 0
    rid = 0
    with tracing(capacity=1 << 15) as rec:
        for _ in range(500):
            if rng.random() < 0.5:
                req = Request(rid, np.zeros(int(rng.integers(4, 9)),
                                            np.int32),
                              max_new=int(rng.integers(4, 15)))
                rid += 1
                if sched.submit(req) is not None:
                    shed_ref += 1
            plan = sched.tick()
            admitted_ref += len(plan.admitted)
            preempt_ref += len(plan.preempted)
            if plan.prefill is not None:
                seq, _, chunk = plan.prefill
                sched.note_prefill(seq, chunk)
                if not seq.prefilling:
                    seq.req.out.append(0)    # last-chunk logits seed decode
            for seq in plan.decode:
                seq.req.out.append(0)
                sched.note_decode(seq)
            for seq in list(sched.running()):
                if not seq.prefilling and len(seq.req.out) >= seq.req.max_new:
                    seq.req.done = True
                    sched.retire(seq)
            pool.check_invariants(
                block_tables=[s.blocks for s in sched.running()])
    assert sched.stats.admissions == admitted_ref
    assert sched.stats.preemptions == preempt_ref
    assert sched.stats.shed == shed_ref
    assert preempt_ref > 0 and shed_ref > 0  # the workload exercised both
    assert rec.dropped == 0
    actions = Counter(r["action"] for r in rec.records()
                      if r["etype"] == "admission_decision")
    assert actions["admit"] == admitted_ref
    assert actions["preempt"] == preempt_ref
    assert actions["shed"] == shed_ref
    assert actions["wait"] == sched.stats.admission_waits
    assert actions["cancel"] == actions["poison"] == 0


# ---------------------------------------------------------------------------
# Dispatch provenance: tier source + candidate rank + demotion marks
# ---------------------------------------------------------------------------

def test_dispatch_decisions_carry_rank_and_source():
    cache = DispatchCache()
    with tracing() as rec:
        cand, src = cache.best_variant_with_source(MATMUL, TPU_V5E, MM_DATA)
        cache.best_variant(MATMUL, TPU_V5E, MM_DATA)   # memory-LRU hit
    recs = [r for r in rec.records() if r["etype"] == "dispatch_decision"]
    assert len(recs) == 2                    # one record per resolution
    cold, mem = recs
    for r in recs:
        validate_record(r)
        assert r["surface"] == "resolve"
        assert r["source"] == src
        assert r["leaf"] == cand.leaf_index
        assert r["demoted"] == 0
    assert mem["rank"] == cold["rank"]       # the LRU replays the walk rank
    assert cold["rank"] >= 0


def test_demote_lands_in_trace_with_provenance():
    cache = DispatchCache()
    cache.best_variant(MATMUL, TPU_V5E, MM_DATA)       # resolve untraced
    with tracing() as rec:
        new = cache.demote(MATMUL, TPU_V5E, MM_DATA,
                           error=RuntimeError("boom"), tick=5)
        cand2 = cache.best_variant(MATMUL, TPU_V5E, MM_DATA)
    assert cand2 == new                      # the demotion took effect
    degr = [r for r in rec.records() if r["etype"] == "degrade"]
    assert len(degr) == 1
    validate_record(degr[0])
    assert degr[0]["tick"] == 5
    assert "boom" in degr[0]["error"]
    post = [r for r in rec.records() if r["etype"] == "dispatch_decision"]
    assert post and post[-1]["demoted"] >= 1  # marks visible to dispatch


# ---------------------------------------------------------------------------
# Registry: snapshot / render_text / summary_line
# ---------------------------------------------------------------------------

def test_registry_snapshot_render_and_summary():
    pool = PagedKVPool(9, 4)
    sched = Scheduler(pool, max_batch=2, max_len=16)
    rec = FlightRecorder(capacity=16)
    rec.emit(_adm(0))
    reg = ObsRegistry(pool=pool, sched=sched, recorder=rec)
    snap = reg.snapshot()
    assert snap["pool"]["capacity"] == 8
    assert snap["pool"]["peak_live"] == 0
    assert snap["sched"]["ticks"] == 0
    assert snap["recorder"] == {"emitted": 1, "buffered": 1, "dropped": 0,
                                "capacity": 16, "sample_frozen_every": 0}
    assert snap["monitor"] == {} and snap["watchdog"] == {}
    lines = reg.render_text().splitlines()
    assert "repro_pool_capacity 8" in lines
    assert "repro_recorder_emitted 1" in lines
    assert lines == sorted(lines)            # stable exposition order
    for line in lines:
        name, value = line.rsplit(" ", 1)
        assert name.startswith("repro_")
        float(value)                         # every value parses numeric
    line = reg.summary_line()
    assert line.startswith("obs ")
    assert "ticks=0" in line and "trace n=1" in line
