"""The dry-run lowering path, in-process on a 1x1 mesh (smoke configs).

The real 512-device dry-run runs as subprocesses (scripts/dryrun_sweep.py);
this exercises the same code — abstract state, shardings, lower, compile,
collective parse — fast enough for CI."""
import dataclasses

import pytest
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed import sharding as dist
from repro.launch import hlo_analysis
from repro.launch.specs import (abstract_state, cache_specs, probe_config,
                                skip_reason, state_shardings,
                                train_batch_specs)
from repro.models.config import SHAPES_BY_NAME, ShapeConfig
from repro.optim import adamw, constant
from repro.runtime.steps import build_serve_steps, build_train_step


def _small_shape(kind):
    return ShapeConfig("t", 64, 4, kind)


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_130m",
                                  "kimi_k2_1t_a32b", "whisper_large_v3",
                                  "hymba_1p5b"])
def test_train_lowering_compiles(arch):
    cfg = get_smoke_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dist.rules_for(cfg, mesh)
    opt = adamw(constant(1e-3))
    shape = _small_shape("train")
    with mesh, dist.use_mesh_rules(mesh, rules):
        params_sds, axes, opt_sds = abstract_state(cfg, opt)
        p_sh, o_sh, _ = state_shardings(cfg, mesh, params_sds, axes, opt_sds)
        batch_sds, batch_sh = train_batch_specs(cfg, shape, mesh)
        step = build_train_step(cfg, opt, microbatches=2)
        lowered = jax.jit(step,
                          in_shardings=(p_sh, o_sh, batch_sh, None),
                          out_shardings=(p_sh, o_sh, None)).lower(
            params_sds, opt_sds, batch_sds,
            jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    cost = hlo_analysis.cost_analysis_dict(compiled)
    assert cost.get("flops", 0) > 0
    rep = hlo_analysis.collective_report(compiled.as_text(), 1)
    assert rep.weighted_bytes >= 0


@pytest.mark.parametrize("arch", ["yi_6b", "hymba_1p5b"])
def test_serve_lowering_compiles(arch):
    cfg = get_smoke_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dist.rules_for(cfg, mesh)
    with mesh, dist.use_mesh_rules(mesh, rules):
        params_sds, axes, _ = abstract_state(cfg, None)
        p_sh, _, _ = state_shardings(cfg, mesh, params_sds, axes, None)
        c_sds, c_sh = cache_specs(cfg, 4, 64, mesh)
        _, decode = build_serve_steps(cfg)
        lowered = jax.jit(decode,
                          in_shardings=(p_sh, None, c_sh, None),
                          out_shardings=(None, c_sh)).lower(
            params_sds, jax.ShapeDtypeStruct((4, 1), jnp.int32), c_sds,
            jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    assert hlo_analysis.cost_analysis_dict(compiled).get("flops", 0) > 0


def test_probe_config_scales_layers_only():
    cfg = get_smoke_config("whisper_large_v3")
    p = probe_config(cfg, 4)
    assert p.layers == 4 and p.encoder.layers == 4
    assert p.d_model == cfg.d_model and p.vocab == cfg.vocab


def test_skip_policy():
    long = SHAPES_BY_NAME["long_500k"]
    assert skip_reason(get_smoke_config("llama3_8b"), long)
    assert skip_reason(get_smoke_config("mamba2_130m"), long) is None
    assert skip_reason(get_smoke_config("hymba_1p5b"), long) is None
    assert skip_reason(get_smoke_config("llama3_8b"),
                       SHAPES_BY_NAME["train_4k"]) is None


def test_unrolled_forward_matches_scanned():
    """Unrolled and scanned layer stacks execute the same math; XLA fuses
    them differently so agreement is at bf16 rounding level, not bitwise."""
    import numpy as np
    from repro.models import forward, init_model
    cfg = get_smoke_config("llama3_8b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    a, _ = forward(params, cfg, tokens)
    b, _ = forward(params, cfg, tokens, unroll=True)
    af = np.asarray(a, np.float32)
    bf = np.asarray(b, np.float32)
    rel = np.abs(af - bf).max() / (np.abs(af).max() + 1e-9)
    assert rel < 0.02, rel
    # ranking-level agreement
    agree = (af.argmax(-1) == bf.argmax(-1)).mean()
    assert agree > 0.9, agree
