"""Hypothesis property tests on system-level invariants (fast, pure CPU)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, SyntheticLM
from repro.launch.hlo_analysis import DTYPE_BYTES, shape_bytes
from repro.models.moe import capacity
from repro.runtime import elastic_mesh_shape


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 4096), st.sampled_from([4, 8, 16, 32]))
def test_elastic_mesh_always_valid(n, prefer):
    data, model = elastic_mesh_shape(n, prefer_model=prefer)
    assert data * model == n                  # every device used
    assert model >= 1 and data >= 1
    assert prefer % model == 0                # model degree only shrinks 2x
    # keeps the preferred degree whenever divisible
    if n % prefer == 0:
        assert model == prefer


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 500), st.integers(2, 64), st.integers(1, 8),
       st.integers(0, 3), st.integers(0, 100))
def test_pipeline_stateless_and_sharded(vocab, seq, batch, seed, step):
    ds = SyntheticLM(DataConfig(vocab=vocab, seq_len=seq, global_batch=batch,
                                seed=seed))
    b1 = ds.batch_at(step)
    b2 = ds.batch_at(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < vocab
    # shifted labels invariant
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # host shards partition the global batch exactly
    if batch >= 2:
        h = batch // 2
        top = ds.batch_at(step, host_slice=slice(0, h))
        bot = ds.batch_at(step, host_slice=slice(h, batch))
        np.testing.assert_array_equal(
            np.concatenate([top["tokens"], bot["tokens"]]), b1["tokens"])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 50))
def test_pipeline_steps_differ(step):
    ds = SyntheticLM(DataConfig(vocab=1000, seq_len=64, global_batch=2))
    a = ds.batch_at(step)["tokens"]
    b = ds.batch_at(step + 1)["tokens"]
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# MoE capacity
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 512), st.integers(1, 16),
       st.floats(0.25, 8.0))
def test_capacity_bounds(gsz, E, k, cf):
    c = capacity(gsz, E, k, cf)
    assert c >= 4
    # with capacity_factor >= 1 and k <= E, total slots cover assignments
    if cf >= 1.0 and k <= E:
        assert E * c >= gsz * k


# ---------------------------------------------------------------------------
# HLO shape parser
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.sampled_from(sorted(DTYPE_BYTES)),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_shape_bytes_roundtrip(dt, dims):
    s = f"{dt}[{','.join(map(str, dims))}]{{{0}}}"
    want = DTYPE_BYTES[dt] * int(np.prod(dims)) if dims else DTYPE_BYTES[dt]
    assert shape_bytes(s) == want


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(sorted(DTYPE_BYTES)),
                          st.lists(st.integers(1, 32), min_size=1,
                                   max_size=3)),
                min_size=1, max_size=4))
def test_shape_bytes_tuples_sum(parts):
    s = "(" + ", ".join(
        f"{dt}[{','.join(map(str, dims))}]" for dt, dims in parts) + ")"
    want = sum(DTYPE_BYTES[dt] * int(np.prod(dims)) for dt, dims in parts)
    assert shape_bytes(s) == want
