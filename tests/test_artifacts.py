"""Artifact round-tripping + O(1) dispatch (PR acceptance criteria).

Covers: serde round-trip for matmul and flash-attention trees (leaf-for-leaf
equality of constraints/plans), the offline compiler's disk artifacts
reloading into trees equal to fresh builds, and the DispatchCache serving a
repeated (family, machine, data) triple without re-invoking
``enumerate_candidates``.
"""
from fractions import Fraction

import pytest

from repro.artifacts import (ArtifactStore, DispatchCache, bucket_key,
                             compile_family, serde)
from repro.artifacts.dispatch import get_default_cache, set_default_cache
from repro.core import (Constraint, ConstraintSystem, Poly, Rel, TPU_V5E, V,
                        best_variant, comprehensive_tree)
from repro.core.select import STATS, rank_candidates
from repro.kernels.flash_attention import FAMILY as FLASH
from repro.kernels.matmul import FAMILY as MATMUL

MM_DATA = {"M": 512, "N": 512, "K": 512}


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    """Tests must not inherit (or pollute) the process-wide dispatch state."""
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


# ---------------------------------------------------------------------------
# serde round-trips
# ---------------------------------------------------------------------------

def test_poly_roundtrip_exact_coefficients():
    p = Fraction(3, 7) * V("x") ** 2 * V("y") - V("z") + Fraction(5, 2)
    q = serde.obj_to_poly(serde.poly_to_obj(p))
    assert p == q
    assert serde.dumps(serde.poly_to_obj(p)) == serde.dumps(
        serde.poly_to_obj(q))                 # canonical bytes are stable


def test_constraint_system_roundtrip():
    C = ConstraintSystem([Constraint.ge(V("a") * V("b") - 4),
                          Constraint.gt(V("a"), 1),
                          Constraint.eq(V("b") - 2)])
    D = serde.obj_to_system(serde.system_to_obj(C))
    assert C == D
    assert [a.rel for a in D.atoms] == [Rel.GE, Rel.GT, Rel.EQ]


@pytest.mark.parametrize("family", [MATMUL, FLASH], ids=lambda f: f.name)
def test_tree_roundtrip_leaf_for_leaf(family):
    leaves = comprehensive_tree(family)
    back = serde.obj_to_tree(serde.tree_to_obj(family.name, leaves))
    assert len(back) == len(leaves)
    for orig, new in zip(leaves, back):
        assert new.constraints == orig.constraints
        assert new.plan == orig.plan
        assert new.applied == orig.applied
    assert back == list(leaves)


def test_store_load_tree_equals_fresh(tmp_path):
    store = ArtifactStore(tmp_path)
    leaves = comprehensive_tree(MATMUL)
    store.save_tree(MATMUL.name, leaves)
    assert store.load_tree(MATMUL.name) == list(leaves)


def test_format_version_mismatch_is_cache_miss(tmp_path):
    store = ArtifactStore(tmp_path)
    store.save_tree(MATMUL.name, comprehensive_tree(MATMUL))
    path = store.tree_path(MATMUL.name)
    text = path.read_text().replace(
        f'"format":{serde.FORMAT_VERSION}', '"format":999999', 1)
    path.write_text(text)
    assert store.load_tree(MATMUL.name) is None      # rebuild, never crash


def test_stale_dispatch_version_is_cache_miss_not_error(tmp_path):
    """ROADMAP version policy: a dispatch table from another FORMAT_VERSION
    must fall through to a cold rebuild — same answer, no exception."""
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_DATA])
    path = store.dispatch_path(MATMUL.name, TPU_V5E.name)
    text = path.read_text().replace(
        f'"format":{serde.FORMAT_VERSION}', '"format":999999', 1)
    path.write_text(text)
    assert store.load_dispatch(MATMUL.name, TPU_V5E.name) is None
    cache = DispatchCache(store=store)
    STATS.reset()
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_DATA)   # must not raise
    assert cache.stats.disk_hits == 0 and cache.stats.cold_builds == 1
    assert STATS.enumerate_calls == 1                      # true cold path
    assert cand == best_variant(MATMUL, TPU_V5E, MM_DATA, use_cache=False)


def test_mangled_dispatch_entries_fall_back_to_cold(tmp_path):
    """A payload that parses as JSON but carries malformed bucket entries
    (e.g. a renamed ``score`` field) is a cache miss, never an exception."""
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_DATA])
    path = store.dispatch_path(MATMUL.name, TPU_V5E.name)
    path.write_text(path.read_text().replace('"score"', '"scorx"'))
    cache = DispatchCache(store=store)
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_DATA)   # must not raise
    assert cache.stats.cold_builds == 1
    assert cand == best_variant(MATMUL, TPU_V5E, MM_DATA, use_cache=False)


# ---------------------------------------------------------------------------
# DispatchCache: memory LRU tier
# ---------------------------------------------------------------------------

def test_second_best_variant_skips_enumeration():
    """Acceptance: the repeat call never touches enumerate_candidates."""
    cache = get_default_cache()
    STATS.reset()
    first = best_variant(MATMUL, TPU_V5E, MM_DATA)
    cold_calls = STATS.enumerate_calls
    assert cold_calls >= 1
    second = best_variant(MATMUL, TPU_V5E, MM_DATA)
    assert STATS.enumerate_calls == cold_calls       # no new enumeration
    assert second == first
    assert cache.stats.memory_hits >= 1


def test_cached_equals_cold_path():
    cached = best_variant(MATMUL, TPU_V5E, MM_DATA)
    cold = best_variant(MATMUL, TPU_V5E, MM_DATA, use_cache=False)
    assert cached == cold


def test_lru_eviction_bounds_memory():
    cache = DispatchCache(maxsize=2)
    for n in (128, 256, 512):
        cache.best_variant(MATMUL, TPU_V5E, {"M": n, "N": n, "K": n})
    assert len(cache) == 2


# ---------------------------------------------------------------------------
# Offline compiler + disk tier
# ---------------------------------------------------------------------------

def test_compiled_artifact_serves_without_enumeration(tmp_path):
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_DATA])

    cache = DispatchCache(store=store)
    STATS.reset()
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_DATA)
    assert STATS.enumerate_calls == 0                # disk tier, no search
    assert cache.stats.disk_hits == 1
    assert cand == best_variant(MATMUL, TPU_V5E, MM_DATA, use_cache=False)


def test_disk_tier_revalidates_off_grid_shapes(tmp_path):
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_DATA])
    cache = DispatchCache(store=store)
    # 500 buckets to 512: the precompiled ranking serves, but only after the
    # exact-shape constraint check passes
    off = {"M": 500, "N": 500, "K": 500}
    assert bucket_key(off) == bucket_key(MM_DATA)
    cand = cache.best_variant(MATMUL, TPU_V5E, off)
    binding = {**TPU_V5E.bindings(), **off, **cand.assignment}
    tree = comprehensive_tree(MATMUL)
    from repro.core import Verdict
    assert tree[cand.leaf_index].constraints.subs(binding).check(
        samples=64) is not Verdict.INCONSISTENT


def test_compile_script_tree_equals_fresh(tmp_path):
    """Acceptance: scripts/compile_artifacts.py output reloads equal."""
    import subprocess, sys, os
    repo = os.path.join(os.path.dirname(__file__), "..")
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "scripts", "compile_artifacts.py"),
         "--family", "matmul", "--machine", "tpu_v5e",
         "--out", str(tmp_path), "--quick", "--verify"],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "verify: reloaded == fresh" in proc.stdout
    # and the artifact is readable from this process too
    reloaded = ArtifactStore(tmp_path).load_tree("matmul")
    assert reloaded == comprehensive_tree(MATMUL)


def test_rank_candidates_accepts_disk_leaves(tmp_path):
    store = ArtifactStore(tmp_path)
    store.save_tree(MATMUL.name, comprehensive_tree(MATMUL))
    disk = rank_candidates(MATMUL, TPU_V5E, MM_DATA,
                           leaves=store.load_tree(MATMUL.name))
    fresh = rank_candidates(MATMUL, TPU_V5E, MM_DATA)
    assert disk[0] == fresh[0]
