"""The hand-written all-to-all MoE path must equal the dense GShard path.

Runs in a subprocess with 8 forced host devices so the shard_map actually
exchanges data over a (2x2x2) pod x data x model mesh.
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed import sharding as dist
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.moe import init_moe, moe_block
    from repro.models.moe_a2a import moe_block_a2a

    cfg = ModelConfig(
        name="a2a-test", layers=1, d_model=32, heads=4, kv_heads=2,
        d_ff=48, vocab=64, block="attn_moe",
        moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=48,
                      capacity_factor=64.0))     # dropless => paths agree

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = dist.rules_for(cfg, mesh)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    with mesh, dist.use_mesh_rules(mesh, rules):
        y_ref, aux_ref = jax.jit(
            lambda p, x: moe_block(p, x, cfg, group_size=8))(p, x)
        y_a2a, aux_a2a = jax.jit(
            lambda p, x: moe_block_a2a(p, x, cfg, group_size=8))(p, x)

    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_a2a), float(aux_ref), rtol=1e-3)

    # gradients flow and match through the a2a schedule
    def loss(fn):
        def f(p):
            y, aux = fn(p, x, cfg, group_size=8)
            return jnp.sum(y * y) + 0.01 * aux
        return f
    with mesh, dist.use_mesh_rules(mesh, rules):
        g_ref = jax.jit(jax.grad(loss(moe_block)))(p)
        g_a2a = jax.jit(jax.grad(loss(moe_block_a2a)))(p)
    for k in ("router", "wi", "wg", "wo"):
        np.testing.assert_allclose(np.asarray(g_a2a[k]),
                                   np.asarray(g_ref[k]),
                                   rtol=2e-3, atol=2e-3)
    print("A2A_OK")
""")


def test_moe_a2a_matches_dense():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "A2A_OK" in r.stdout, r.stdout + r.stderr
