"""Prefix-sharing + copy-on-write + async tick overlap correctness.

The contract under test: turning ``prefix_sharing`` or ``async_depth`` on
must never change a single output token.  Shared-prefix requests map
resident KV blocks instead of re-prefilling them — paged attention reads
KV through block tables and masks by logical position, and block-aligned
sharing preserves both token content and absolute positions, so mapped
blocks are bit-identical to recomputed ones.  Writes into shared blocks
go through device-side copy-on-write, so divergence after a shared prefix
must never corrupt a sibling, and preempting the sequence that *wrote* a
shared block must leave the survivor's mapped copy intact.
"""
import numpy as np
import pytest
import jax

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.runtime import ServeEngine


def _serve(cfg, params, prompts, *, max_new=6, eos=None, staged=True,
           **eng_kw):
    """Drive one engine over ``prompts``; ``staged`` drains the first
    prompt (the leader) before submitting the rest, so followers admit
    against a populated prefix index.  Returns (outputs in submit order,
    engine)."""
    eng = ServeEngine(cfg, params, **eng_kw)
    outs = {}
    rids = [eng.submit(prompts[0], max_new=max_new, eos=eos)]
    if staged:
        for r in eng.run_until_drained():
            outs[r.rid] = r.out
    for p in prompts[1:]:
        rids.append(eng.submit(p, max_new=max_new, eos=eos))
    for r in eng.run_until_drained():
        outs[r.rid] = r.out
    eng.pool.check_invariants([s.blocks for s in eng.sched.running()])
    assert set(outs) == set(rids)
    return [outs[r] for r in rids], eng


def _shared_prefix_prompts(cfg, rng, *, n=3, shared=22, tail=6):
    """A leader plus ``n`` followers sharing its first ``shared`` tokens;
    22 % page_size(4) != 0 diverges mid-block, so followers map a partial
    tail block and must CoW it."""
    lead = rng.integers(0, cfg.vocab, shared + 2).astype(np.int32)
    prompts = [lead]
    for _ in range(n):
        prompts.append(np.concatenate(
            [lead[:shared], rng.integers(0, cfg.vocab, tail)]
        ).astype(np.int32))
    return prompts


_ENG = dict(max_batch=4, max_len=64, page_size=4, prefill_chunk=8)


def test_shared_prefix_parity_and_cow_dense():
    """Sharing on == sharing off, token for token, with real prefix hits
    and real CoW copies (mid-block divergence) — and both pipeline depths
    agree."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prefix_prompts(cfg, np.random.default_rng(0))
    base, _ = _serve(cfg, params, prompts, prefix_sharing=False, **_ENG)
    for depth in (1, 2):
        got, eng = _serve(cfg, params, prompts, prefix_sharing=True,
                          async_depth=depth, **_ENG)
        assert got == base, f"async_depth={depth}"
        assert eng.pool.stats.prefix_tokens_saved > 0
        assert eng.pool.stats.cow_copies >= len(prompts) - 1


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_130m", "hymba_1p5b"])
def test_shared_prefix_parity_across_families(arch):
    """Dense shares; SSM-bearing configs (recurrent state cannot skip
    prompt tokens) silently force sharing off — either way the outputs
    must match the no-sharing engine exactly."""
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prefix_prompts(cfg, np.random.default_rng(1))
    base, _ = _serve(cfg, params, prompts, prefix_sharing=False, **_ENG)
    got, eng = _serve(cfg, params, prompts, prefix_sharing=True,
                      async_depth=2, **_ENG)
    assert got == base
    if cfg.block in ("ssm", "hybrid"):
        assert not eng.prefix_sharing
        assert eng.pool.stats.prefix_hits == 0
    else:
        assert eng.pool.stats.prefix_hits > 0


def test_divergence_after_shared_prefix_leaves_sibling_intact():
    """Two concurrent followers of the same prefix diverge mid-block: each
    CoWs its own copy of the partial tail block, so neither corrupts the
    other (or the cached original — a third, later follower still maps a
    pristine prefix)."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = _shared_prefix_prompts(cfg, rng, n=2)
    late = np.concatenate(
        [prompts[0][:22], rng.integers(0, cfg.vocab, 7)]).astype(np.int32)
    base, _ = _serve(cfg, params, prompts + [late],
                     prefix_sharing=False, **_ENG)
    got, eng = _serve(cfg, params, prompts + [late],
                      prefix_sharing=True, **_ENG)
    assert got == base
    assert eng.pool.stats.cow_copies >= 3


def test_preempting_shared_block_holder_keeps_survivor_intact():
    """A pool too tight for both sequences preempts the youngest while it
    holds blocks mapped from the survivor's prefix chain; the survivor
    (and the preempted request, recomputed after re-admission) must still
    produce exactly the roomy pool's tokens."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    lead = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    follow = np.concatenate(
        [lead[:8], rng.integers(0, cfg.vocab, 2)]).astype(np.int32)
    kw = dict(max_batch=2, max_len=28, page_size=4, prefill_chunk=8,
              prefix_sharing=True, watermark_blocks=0)
    roomy, _ = _serve(cfg, params, [lead, follow], max_new=14,
                      staged=False, num_blocks=100, **kw)
    tight, eng = _serve(cfg, params, [lead, follow], max_new=14,
                        staged=False, num_blocks=9, **kw)
    assert eng.sched.stats.preemptions > 0
    assert tight == roomy
    assert eng.pool.num_live == eng.pool.num_reclaimable  # only cache left


def test_async_overlap_parity_with_eos_and_preemption():
    """``async_depth=2`` (host plans tick t+1 while the device executes
    tick t) must commit exactly the synchronous engine's outputs — with
    EOS truncation reconciled at the commit barrier, and with in-flight
    tokens of a preempted sequence discarded and regenerated."""
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(2), cfg)
    # EOS: discover the greedy first token, then serve with it as EOS —
    # depth 2 dispatches speculative tokens past it; commit must truncate
    eng = ServeEngine(cfg, params, max_batch=2, max_len=48)
    eng.submit(np.arange(6), max_new=1)
    first = eng.run_until_drained()[0].out[0]
    for depth in (1, 2, 3):
        e = ServeEngine(cfg, params, max_batch=2, max_len=48,
                        async_depth=depth)
        e.submit(np.arange(6), max_new=16, eos=first)
        done = e.run_until_drained()
        assert [r.out for r in done] == [[first]], f"async_depth={depth}"
    # preemption under overlap: tight pool, uncommitted in-flight tokens
    # of the victim must be dropped (dead), then regenerated exactly
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(2)]
    kw = dict(max_batch=2, max_len=24, page_size=4, prefill_chunk=8,
              watermark_blocks=0, staged=False)
    roomy, _ = _serve(cfg, params, prompts, max_new=12, num_blocks=100,
                      async_depth=1, **kw)
    for depth in (1, 2):
        tight, eng = _serve(cfg, params, prompts, max_new=12, num_blocks=7,
                            async_depth=depth, **kw)
        assert eng.sched.stats.preemptions > 0
        assert tight == roomy, f"async_depth={depth}"
