"""Data pipeline, optimizers, checkpointing, fault-tolerance units."""
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.optim import (adafactor, adamw, clip_by_global_norm, constant,
                         global_norm, warmup_cosine)
from repro.runtime import StragglerMonitor, TrainController, elastic_mesh_shape


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_stateless():
    ds = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=8))
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions_batch():
    ds = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8))
    full = ds.batch_at(3)
    h0 = ds.batch_at(3, host_slice=slice(0, 4))
    h1 = ds.batch_at(3, host_slice=slice(4, 8))
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_data_has_learnable_signal():
    """Bigram successor rule appears at the configured rate."""
    cfg = DataConfig(vocab=997, seq_len=512, global_batch=4,
                     bigram_fraction=0.5)
    ds = SyntheticLM(cfg)
    b = ds.batch_at(0)
    tok, lab = b["tokens"], b["labels"]
    hits = (lab == ds.successor(tok)).mean()
    assert 0.35 < hits < 0.75, hits


def test_prefetch_iterator():
    it = make_pipeline(vocab=64, seq_len=8, global_batch=4, step0=5)
    s, b = next(it)
    assert s == 5 and b["tokens"].shape == (4, 8)
    s2, _ = next(it)
    assert s2 == 6
    it.close()


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.ones((2, 4))}


def test_adamw_descends_quadratic():
    opt = adamw(constant(0.1), weight_decay=0.0)
    params = _quad_params()
    state = opt.init(params)
    loss = lambda p: sum(jnp.sum(x * x) for x in jax.tree.leaves(p))
    for i in range(200):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(i, jnp.int32))
    assert float(loss(params)) < 1e-3


def test_adafactor_descends_and_factored_state():
    # low constant lr: adafactor's rms-clipped updates behave like signSGD,
    # oscillating at amplitude ~lr around the optimum
    opt = adafactor(constant(0.02))
    params = _quad_params()
    state = opt.init(params)
    assert set(state["f"]["b"]) == {"vr", "vc"}       # factored for 2D
    assert state["f"]["b"]["vr"].shape == (2,)
    assert state["f"]["b"]["vc"].shape == (4,)
    assert set(state["f"]["w"]) == {"v"}              # unfactored for 1D
    loss = lambda p: sum(jnp.sum(x * x) for x in jax.tree.leaves(p))
    init_loss = float(loss(params))
    for i in range(400):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(i, jnp.int32))
    assert float(loss(params)) < 0.02 * init_loss


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: untouched
    same, _ = clip_by_global_norm(g, 1e6)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.asarray(100))) <= float(s(jnp.asarray(50)))
    np.testing.assert_allclose(float(s(jnp.asarray(100))), 0.1, atol=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 8)),
                      "b": jnp.arange(3.0)},
            "step_arr": jnp.asarray([seed], jnp.int32)}


def test_checkpoint_roundtrip(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    t = _tree(1)
    mgr.save(10, t)
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(t["layer"]["w"]))


def test_checkpoint_async_and_gc(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.available_steps() == [3, 4]
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 4
    assert int(restored["step_arr"][0]) == 4


def test_checkpoint_corruption_fallback(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt the newest step's data
    d = os.path.join(ckpt_dir, "step_000000002")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\x00\x00\x00\x01")
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 1                      # fell back past the corrupt one
    assert int(restored["step_arr"][0]) == 1


def test_checkpoint_shape_mismatch_raises(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, _tree(1))
    bad_template = {"layer": {"w": jnp.zeros((5, 5)), "b": jnp.zeros(3)},
                    "step_arr": jnp.zeros(1, jnp.int32)}
    step, restored = mgr.restore_latest(bad_template)
    assert restored is None               # nothing valid for this template


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_detection():
    mon = StragglerMonitor(factor=2.0, min_samples=4)
    for _ in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.5)
    assert mon.stragglers() == [2]


def test_straggler_needs_samples():
    mon = StragglerMonitor(min_samples=8)
    mon.record(0, 1.0)
    mon.record(1, 99.0)
    assert mon.stragglers() == []


@pytest.mark.parametrize("n,expect", [
    (512, (32, 16)), (256, (16, 16)), (255, (255, 1)),
    (192, (12, 16)), (8, (1, 8)), (1, (1, 1))])
def test_elastic_mesh_shape(n, expect):
    assert elastic_mesh_shape(n) == expect


def test_train_controller_restarts_from_checkpoint(ckpt_dir):
    """Inject a fault at step 7; controller must restore step 5 state and
    converge to the same final state as a fault-free run (exact replay)."""
    def make_run_step():
        def run_step(state, step):
            return state + step, {"loss": float(state)}
        return run_step

    # fault-free reference
    ref_ctl = TrainController(make_run_step(),
                              CheckpointManager(ckpt_dir + "_ref"),
                              ckpt_every=5)
    ref_state, _ = ref_ctl.run(jnp.asarray(0.0), start_step=0, num_steps=12)

    fired = {"n": 0}

    def fault(step):
        if step == 7 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("injected host failure")

    ctl = TrainController(make_run_step(), CheckpointManager(ckpt_dir),
                          ckpt_every=5, fault_hook=fault)
    state, hist = ctl.run(jnp.asarray(0.0), start_step=0, num_steps=12)
    assert fired["n"] == 1
    assert float(state) == float(ref_state)


def test_train_controller_gives_up_after_retries(ckpt_dir):
    def always_fail(state, step):
        raise RuntimeError("dead host")
    ctl = TrainController(always_fail, CheckpointManager(ckpt_dir),
                          ckpt_every=5, max_retries=2)
    with pytest.raises(RuntimeError):
        ctl.run(jnp.asarray(0.0), start_step=0, num_steps=3)
