"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only the dry-run subprocesses force 512 devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # Registered in pyproject.toml too; re-register here so the marker is
    # known even when pytest is invoked from outside the repo root.  The CI
    # fast tier deselects these with ``-m "not slow"``; the nightly job runs
    # the full suite with ``-m "slow or not slow"``.
    config.addinivalue_line(
        "markers",
        "slow: long-running model/system tests "
        "(excluded from the CI fast tier via -m 'not slow')")
