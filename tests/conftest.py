"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only the dry-run subprocesses force 512 devices.

Determinism: every randomized test draws from the ``rng`` fixture (or an
explicitly seeded generator) — never the global ``np.random`` state — so
the suite is safe under test-order randomization (``pytest-randomly`` or
``pytest -p no:randomly`` both yield identical results; no test may depend
on RNG state another test advanced).  The fake-clock/skewed-timer fixtures
below are the drift-injection half of ``tests/test_adaptive.py``: they
fabricate deterministic wall-clock measurements so adaptive-serving tests
never time real kernels.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

#: One seed for every randomized fixture; change in one place to shake the
#: whole suite.
TEST_SEED = 1234


def pytest_configure(config):
    # Registered in pyproject.toml too; re-register here so the marker is
    # known even when pytest is invoked from outside the repo root.  The CI
    # fast tier deselects these with ``-m "not slow"``; the nightly job runs
    # the full suite with ``-m "slow or not slow"``.
    config.addinivalue_line(
        "markers",
        "slow: long-running model/system tests "
        "(excluded from the CI fast tier via -m 'not slow')")


@pytest.fixture
def rng():
    """Deterministic per-test RNG — the only sanctioned randomness source
    for randomized tests (drift workloads, reservoir sampling, fuzzed
    shapes)."""
    return np.random.default_rng(TEST_SEED)


class FakeClock:
    """A controllable monotonic clock for timing-dependent tests."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, dt: float) -> None:
        self.now += float(dt)

    def __call__(self) -> float:
        return self.now


@pytest.fixture
def fake_clock():
    return FakeClock()


class SkewedTimer:
    """A deterministic ``repro.tuning.measure.Timer`` whose measurements
    are dictated per candidate — the drift-injection harness.

    ``skews`` maps a candidate key (``repro.runtime.monitor.cand_key``) to
    the seconds-per-repeat it should "measure"; ``default`` covers every
    other candidate.  Re-skew mid-test (``timer.skews[key] = ...``) to
    fabricate a traffic shift.  Tiny seeded jitter keeps medians honest
    without ever reordering candidates."""

    def __init__(self, default: float = 1e-3, jitter: float = 0.0,
                 seed: int = TEST_SEED):
        self.default = float(default)
        self.jitter = float(jitter)
        self.skews = {}
        self.calls = []                      # (family, cand_key, data)
        self._rng = np.random.default_rng(seed)

    def __call__(self, family, plan, assignment, data, cfg):
        key = tuple(sorted((k, int(v)) for k, v in assignment.items()))
        base = None
        for (leaf, asg), secs in self.skews.items():
            if asg == key:
                base = float(secs)
                break
        if base is None:
            base = self.default
        self.calls.append((family.name, key, dict(data)))
        out = []
        for _ in range(max(1, cfg.iters)):
            j = (self._rng.uniform(-self.jitter, self.jitter)
                 if self.jitter else 0.0)
            out.append(base * (1.0 + j))
        return out


@pytest.fixture
def skewed_timer():
    return SkewedTimer()
