"""Measurement-calibrated dispatch tables (repro.tuning + FORMAT_VERSION 2).

Covers the PR acceptance criteria: a tuned (v2) table round-trips
byte-deterministically, a v1 table reads as a cache miss (never an error),
``best_variant`` prefers the measured rank and stays in exact parity with
the symbolic path when no calibration is present, and the few-fit-most
compaction finds a reduced variant set within tolerance.

Measurements are injected through ``measure_table``'s ``timer`` hook — a
deterministic fake keyed on the assignment — so these tests exercise the
full measure -> calibrate -> compact -> dispatch loop without paying for
interpreted Pallas.
"""
import pytest

from repro.artifacts import (ArtifactStore, DispatchCache, bucket_key,
                             compile_family, serde)
from repro.artifacts.dispatch import set_default_cache
from repro.core import TPU_V5E, best_variant
from repro.core.select import STATS
from repro.kernels.matmul import FAMILY as MATMUL
from repro.tuning import (MeasureConfig, calibrate_table, compact_table,
                          fit_family, measure_table, parse_bucket_key)
from repro.tuning.calibrate import predict_us
from repro.tuning.measure import clamp_data, trimmed_mean_us

MM_256 = {"M": 256, "N": 256, "K": 256}
MM_512 = {"M": 512, "N": 512, "K": 512}
CFG = MeasureConfig(iters=3, warmup=0, trim=1, max_dim=512, top_k=4)


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


def fake_timer(family, plan, assignment, data, cfg):
    """Deterministic stand-in for kernel wall time: cheaper for small ``s``,
    which *inverts* the symbolic preference (the symbolic model ranks large
    ``s`` variants first at these shapes) — so a measured-rank win is
    observable."""
    us = 100.0 * assignment["s"] + 0.01 * assignment["bk"]
    return [us * 1e-6] * cfg.iters


def _tuned_store(tmp_path, shapes, tolerance=0.10, timer=fake_timer):
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=shapes)
    table = store.load_dispatch(MATMUL.name, TPU_V5E.name)
    samples = measure_table(MATMUL, table, CFG, timer=timer)
    tuned = calibrate_table(MATMUL, table, samples, meta={"fake": True})
    tuned = compact_table(tuned, samples, tolerance=tolerance)
    store.save_dispatch(tuned)
    return store, tuned, samples


# ---------------------------------------------------------------------------
# measure helpers
# ---------------------------------------------------------------------------

def test_parse_bucket_key_inverts_bucket_key():
    assert parse_bucket_key(bucket_key(MM_512)) == MM_512
    assert parse_bucket_key(bucket_key({"SQ": 4096, "HD": 64})) == \
        {"SQ": 4096, "HD": 64}
    with pytest.raises(ValueError):
        parse_bucket_key("nodigits")


def test_clamp_and_trimmed_mean():
    assert clamp_data({"M": 4096, "N": 128}, 256) == {"M": 256, "N": 128}
    # trim=1 drops the 1.0 outlier and the 0.1 minimum
    assert trimmed_mean_us([0.3, 1.0, 0.1, 0.3, 0.3], trim=1) == \
        pytest.approx(0.3e6)


def test_measure_failure_is_data_not_error(tmp_path):
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_512])
    table = store.load_dispatch(MATMUL.name, TPU_V5E.name)

    def exploding(family, plan, assignment, data, cfg):
        raise RuntimeError("kernel blew up")

    samples = measure_table(MATMUL, table, CFG, timer=exploding)
    assert samples and all(s.us is None for s in samples)
    tuned = compact_table(calibrate_table(MATMUL, table, samples), samples)
    # the all-failed bucket is reported as uncovered, not silently dropped
    comp = tuned["compaction"]
    assert comp["buckets_total"] == 1 and comp["buckets_covered"] == 0
    assert comp["per_bucket"] == {bucket_key(MM_512): None}
    # a bucket with zero successful measurements must NOT get an order —
    # otherwise dispatch would report "measured" for the symbolic ranking
    assert tuned["measured_ranks"] == {}
    store.save_dispatch(tuned)                          # still a valid table
    cache = DispatchCache(store=store)
    assert cache.rank_source(MATMUL, TPU_V5E, MM_512) == "symbolic"
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_512)  # must not raise
    assert cache.stats.measured_hits == 0
    assert cand == best_variant(MATMUL, TPU_V5E, MM_512, use_cache=False)


# ---------------------------------------------------------------------------
# acceptance: measured rank consumed by best_variant
# ---------------------------------------------------------------------------

def test_best_variant_prefers_measured_rank(tmp_path):
    store, tuned, samples = _tuned_store(tmp_path, [MM_512])
    bucket = bucket_key(MM_512)
    # the fake timer must actually disagree with the symbolic order,
    # otherwise this test proves nothing
    order = tuned["measured_ranks"][bucket]["order"]
    assert order[0] != 0
    cache = DispatchCache(store=store)
    STATS.reset()
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_512)
    assert STATS.enumerate_calls == 0                 # disk tier, no search
    assert cache.stats.disk_hits == 1
    assert cache.stats.measured_hits == 1
    fastest = min((s for s in samples if s.us is not None),
                  key=lambda s: s.us)
    assert cand.assignment == fastest.assignment
    symbolic = best_variant(MATMUL, TPU_V5E, MM_512, use_cache=False)
    assert cand.assignment != symbolic.assignment     # the rank really moved


def test_rank_source_reporting(tmp_path):
    store, _, _ = _tuned_store(tmp_path, [MM_512])
    cache = DispatchCache(store=store)
    assert cache.rank_source(MATMUL, TPU_V5E, MM_512) == "measured"
    assert cache.rank_source(MATMUL, TPU_V5E,
                             {"M": 64, "N": 64, "K": 64}) == "cold"
    assert DispatchCache().rank_source(MATMUL, TPU_V5E, MM_512) == "cold"


def test_parity_with_symbolic_when_untuned(tmp_path):
    """No calibration section => byte-identical behaviour to PR-1 dispatch."""
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_512])
    cache = DispatchCache(store=store)
    assert cache.rank_source(MATMUL, TPU_V5E, MM_512) == "symbolic"
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_512)
    assert cache.stats.measured_hits == 0
    assert cand == best_variant(MATMUL, TPU_V5E, MM_512, use_cache=False)


def test_mangled_measured_ranks_degrade_to_symbolic(tmp_path):
    """Malformed tuning sections are ignored, never raised (cache-miss-
    never-error, applied to the v2 sections)."""
    store, tuned, _ = _tuned_store(tmp_path, [MM_512])
    bucket = bucket_key(MM_512)
    for bad_order in ([99, 98], ["x"], "notalist", [0, 0, 1]):
        mangled = dict(tuned)
        mangled["measured_ranks"] = {bucket: {"order": bad_order}}
        store.save_dispatch(mangled)
        cache = DispatchCache(store=store)
        cand = cache.best_variant(MATMUL, TPU_V5E, MM_512)   # must not raise
        assert cache.stats.disk_hits == 1
        assert cache.stats.measured_hits == 0
        assert cand == best_variant(MATMUL, TPU_V5E, MM_512, use_cache=False)


# ---------------------------------------------------------------------------
# acceptance: v2 round-trip + v1 cache miss
# ---------------------------------------------------------------------------

def test_tuned_table_roundtrips_byte_deterministically(tmp_path):
    store, tuned, _ = _tuned_store(tmp_path, [MM_256, MM_512])
    assert tuned["format"] == serde.FORMAT_VERSION == 2
    reloaded = store.load_dispatch(MATMUL.name, TPU_V5E.name)
    assert serde.dumps(reloaded) == serde.dumps(tuned)
    # and a save -> load -> save cycle is a fixed point (no float drift)
    store.save_dispatch(reloaded)
    again = store.load_dispatch(MATMUL.name, TPU_V5E.name)
    assert serde.dumps(again) == serde.dumps(tuned)
    assert "calibration" in again and "measured_ranks" in again


def test_v1_table_is_cache_miss_not_error(tmp_path):
    store, tuned, _ = _tuned_store(tmp_path, [MM_512])
    path = store.dispatch_path(MATMUL.name, TPU_V5E.name)
    path.write_text(path.read_text().replace('"format":2', '"format":1', 1))
    assert store.load_dispatch(MATMUL.name, TPU_V5E.name) is None
    cache = DispatchCache(store=store)
    STATS.reset()
    cand = cache.best_variant(MATMUL, TPU_V5E, MM_512)       # must not raise
    assert cache.stats.cold_builds == 1 and STATS.enumerate_calls == 1
    assert cand == best_variant(MATMUL, TPU_V5E, MM_512, use_cache=False)


# ---------------------------------------------------------------------------
# calibration fit + compaction
# ---------------------------------------------------------------------------

def test_calibration_fit_predicts_positive_times(tmp_path):
    store, tuned, samples = _tuned_store(tmp_path, [MM_256, MM_512])
    cal = tuned["calibration"]
    assert cal["n_samples"] == sum(s.us is not None for s in samples)
    assert cal["rms_log_residual"] >= 0
    table = store.load_dispatch(MATMUL.name, TPU_V5E.name)
    fit = fit_family(MATMUL, table, samples)
    leaf = serde.obj_to_leaf(
        table["leaves"][str(samples[0].leaf_index)])
    p = predict_us(fit, MATMUL, leaf.plan, samples[0].assignment,
                   samples[0].data, table["machine_bindings"])
    assert p is not None and p > 0


def test_compaction_finds_reduced_covering_set(tmp_path):
    """Acceptance: >= 1 bucket where a reduced variant set stays within
    tolerance.  The fake timer makes one variant fastest everywhere, so the
    greedy cover must collapse every bucket onto a single variant."""
    _, tuned, samples = _tuned_store(tmp_path, [MM_256, MM_512])
    comp = tuned["compaction"]
    assert comp["buckets_total"] == 2
    assert comp["buckets_covered"] == comp["buckets_total"]
    assert len(comp["variants"]) < comp["total_variants_measured"]
    assert len(comp["variants"]) == 1
    covered = [b for b, rec in comp["per_bucket"].items()
               if rec is not None and rec["regret"] <= comp["tolerance"]]
    assert len(covered) >= 1


def test_compaction_respects_tolerance(tmp_path):
    """With zero tolerance every bucket needs its exact argmin variant."""

    def per_bucket_best(family, plan, assignment, data, cfg):
        # fastest variant differs per bucket: s=2 at 256, s=8 at 512
        want = 2 if data["M"] <= 256 else 8
        us = 10.0 if assignment["s"] == want else 1000.0 + assignment["bk"]
        return [us * 1e-6] * max(1, cfg.iters)

    _, tuned, _ = _tuned_store(tmp_path, [MM_256, MM_512], tolerance=0.0,
                               timer=per_bucket_best)
    comp = tuned["compaction"]
    assert comp["buckets_covered"] == comp["buckets_total"] == 2
    assert len(comp["variants"]) == 2


def test_compaction_tie_break_prefers_lower_regret():
    """Two variants covering the same buckets: the greedy cover must pick
    the one with lower total relative regret."""
    from repro.tuning.compact import compact_table as ct
    from repro.tuning.measure import MeasuredSample

    def sample(bucket, pos, asg, us):
        return MeasuredSample(bucket=bucket, entry_index=pos, leaf_index=0,
                              assignment=asg, score=1.0,
                              data={"M": 256}, us=us)

    samples = [
        sample("M256", 0, {"s": 1}, 100.0),   # best
        sample("M256", 1, {"s": 2}, 101.0),   # regret 0.01
        sample("M256", 2, {"s": 4}, 108.0),   # regret 0.08
        sample("M512", 0, {"s": 1}, 200.0),   # best
        sample("M512", 1, {"s": 2}, 202.0),   # regret 0.01
        sample("M512", 2, {"s": 4}, 216.0),   # regret 0.08
    ]
    # drop the per-bucket best so s=2 and s=4 both cover both buckets and
    # tie on coverage; only regret can break the tie
    tied = [s for s in samples if s.assignment["s"] != 1]
    comp = ct({"buckets": {}}, tied, tolerance=0.10)["compaction"]
    assert comp["variants"] == ["leaf0|s=2"]


def test_warm_kernel_dispatch_reports_rank_source():
    """Serving warm-up labels every pick with the tier that decided it
    (stats-delta attribution); with no artifact store everything is cold."""
    from repro.configs import get_smoke_config
    from repro.runtime.serving import warm_kernel_dispatch
    picks = warm_kernel_dispatch(get_smoke_config("llama3_8b"), max_len=128)
    assert picks
    for info in picks.values():
        assert info["rank_source"] == "cold"
        assert info["candidate"].score >= 0


# ---------------------------------------------------------------------------
# CLI smoke (the CI dry-run contract)
# ---------------------------------------------------------------------------

def test_tune_artifacts_cli_dry_run(tmp_path):
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E], shapes=[MM_512])
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "scripts", "tune_artifacts.py"),
         "--family", "matmul", "--machine", "tpu_v5e",
         "--out", str(tmp_path), "--dry-run"],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "[dry-run] matmul/tpu_v5e" in proc.stdout
    # dry run plans but never measures: the table on disk is unchanged (v2,
    # no tuning sections)
    table = store.load_dispatch(MATMUL.name, TPU_V5E.name)
    assert "measured_ranks" not in table
