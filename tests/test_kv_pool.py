"""KV pool + scheduler invariants: churn, admission head-room, preemption,
refcounted prefix sharing, starvation bound.  Pure host-side — no jax, no
device work."""
import numpy as np
import pytest

from repro.runtime.kv_pool import GARBAGE_BLOCK, PREFIX_ROOT, PagedKVPool
from repro.runtime.scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------

def test_fresh_pool_allocates_in_order():
    pool = PagedKVPool(num_blocks=9, page_size=4)
    assert pool.capacity == 8
    assert pool.alloc(3) == [1, 2, 3]
    assert pool.alloc(2) == [4, 5]


def test_alloc_is_all_or_nothing():
    pool = PagedKVPool(num_blocks=5, page_size=4)
    got = pool.alloc(3)
    assert got == [1, 2, 3]
    before = pool.num_free
    assert pool.alloc(2) is None            # only 1 free: refuse whole grant
    assert pool.num_free == before          # nothing leaked from the refusal
    assert pool.stats.alloc_failures == 1
    pool.free(got)
    assert pool.alloc(4) is not None


def test_blocks_for_rounds_up():
    pool = PagedKVPool(num_blocks=8, page_size=16)
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(16) == 1
    assert pool.blocks_for(17) == 2


def test_double_free_raises():
    pool = PagedKVPool(num_blocks=4, page_size=2)
    got = pool.alloc(2)
    pool.free(got)
    with pytest.raises(ValueError):
        pool.free(got)
    with pytest.raises(ValueError):
        pool.free([GARBAGE_BLOCK])


def test_garbage_block_never_circulates():
    pool = PagedKVPool(num_blocks=6, page_size=2)
    seen = set()
    for _ in range(40):
        got = pool.alloc(3)
        seen.update(got)
        pool.free(got)
    assert GARBAGE_BLOCK not in seen
    pool.check_invariants()


def test_churn_1k_cycles_no_leaks():
    """1k submit/retire-shaped alloc/free cycles: deterministic given the
    seed, invariants hold throughout, and the drained pool is exactly full
    again (no leaked, minted, or lost blocks)."""
    pool = PagedKVPool(num_blocks=33, page_size=16)
    rng = np.random.default_rng(0)
    live = []
    for i in range(1000):
        n = int(rng.integers(1, 6))
        got = pool.alloc(n)
        if got is not None:
            live.append(got)
        # retire a random victim when the pool tightens
        if (got is None or rng.random() < 0.4) and live:
            pool.free(live.pop(int(rng.integers(len(live)))))
        if i % 100 == 0:
            pool.check_invariants()
    for blocks in live:
        pool.free(blocks)
    pool.check_invariants()
    assert pool.num_live == 0
    assert pool.num_free == pool.capacity


# ---------------------------------------------------------------------------
# Refcounts + prefix index
# ---------------------------------------------------------------------------

def test_refcounted_free_returns_block_only_at_zero():
    pool = PagedKVPool(num_blocks=5, page_size=4)
    got = pool.alloc(2)
    pool.incref(got)                         # second owner
    assert all(pool.is_shared(b) for b in got)
    pool.free(got)                           # first owner drops
    assert pool.num_live == 2                # still live: one owner left
    assert pool.num_free == 2
    pool.free(got)                           # last owner drops
    assert pool.num_live == 0
    assert pool.num_free == pool.capacity
    with pytest.raises(ValueError):          # refcount can never go negative
        pool.free(got)
    with pytest.raises(ValueError):          # incref needs a live block
        pool.incref([got[0]])


def test_register_and_match_full_prefix():
    pool = PagedKVPool(num_blocks=9, page_size=4)
    toks = list(range(100, 112))             # 3 full blocks
    got = pool.alloc(3)
    h = PREFIX_ROOT
    for i, b in enumerate(got):
        h = pool.register_prefix(h, toks[i * 4:(i + 1) * 4], b)
    # a longer prompt sharing all 3 blocks maps them and prefills the rest
    blocks, matched, chash = pool.match_prefix(toks + [7, 8])
    assert blocks == got and matched == 12 and chash == h
    assert all(pool.is_shared(b) for b in got)
    assert pool.stats.prefix_hits == 3
    assert pool.stats.prefix_tokens_saved == 12
    pool.free(blocks)                        # the mapper retires
    pool.free(got)                           # the owner retires
    assert pool.num_live == 3                # index pins keep them resident
    assert pool.num_reclaimable == 3
    pool.check_invariants()


def test_match_prefix_caps_below_full_prompt():
    """A prompt fully covered by the index must still prefill >= 1 token —
    its last-position logits seed decode."""
    pool = PagedKVPool(num_blocks=9, page_size=4)
    toks = list(range(50, 58))               # 2 full blocks
    got = pool.alloc(2)
    h = pool.register_prefix(PREFIX_ROOT, toks[:4], got[0])
    pool.register_prefix(h, toks[4:], got[1])
    blocks, matched, chash = pool.match_prefix(toks)
    assert matched == 7                      # capped at len - 1
    assert blocks == got                     # block 2 still mapped (partial)
    assert chash == h                        # chain covers full blocks only


def test_match_prefix_partial_tail_block():
    """Divergence mid-block: the best-overlap registered child block is
    mapped too (its tail is wrong but masked off), so the mapper's first
    write into it must CoW — is_shared says so."""
    pool = PagedKVPool(num_blocks=9, page_size=4)
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    got = pool.alloc(2)
    h = pool.register_prefix(PREFIX_ROOT, toks[:4], got[0])
    pool.register_prefix(h, toks[4:], got[1])
    # shares block 1 fully, then 2 of block 2's tokens, then diverges
    blocks, matched, chash = pool.match_prefix([1, 2, 3, 4, 5, 6, 9, 9, 9])
    assert blocks == got and matched == 6 and chash == h
    assert pool.is_shared(got[1])
    # no overlap at all: no mapping, miss counted
    blocks, matched, _ = pool.match_prefix([9, 9, 9, 9])
    assert blocks == [] and matched == 0
    assert pool.stats.prefix_misses == 1


def test_alloc_reclaims_idle_cached_blocks_lru():
    """Cached prefix blocks nobody maps are free-in-waiting: alloc evicts
    them (oldest first) instead of refusing; mapped blocks are protected."""
    pool = PagedKVPool(num_blocks=5, page_size=2)
    got = pool.alloc(4)                      # pool now empty
    h1 = pool.register_prefix(PREFIX_ROOT, [1, 2], got[0])
    pool.register_prefix(h1, [3, 4], got[1])
    pool.register_prefix(PREFIX_ROOT, [5, 6], got[2])
    pool.free(got)                           # owner gone; 3 cached + 1 free
    assert pool.num_free == 1 and pool.num_reclaimable == 3
    # map [1,2] so its block is protected from eviction
    blocks, matched, _ = pool.match_prefix([1, 2, 9])
    assert matched == 2
    assert pool.alloc(3) is not None         # evicts the 2 idle cached
    assert pool.stats.cache_evictions == 2
    assert pool.num_reclaimable == 0
    assert pool.alloc(1) is None             # mapped block is NOT evictable
    pool.check_invariants()


def test_check_invariants_block_table_disjoint_from_free_list():
    pool = PagedKVPool(num_blocks=6, page_size=4)
    table = pool.alloc(2)
    pool.check_invariants(block_tables=[table])
    stolen = table[0]
    pool.free([stolen])                      # table entry now on free list
    with pytest.raises(AssertionError, match="free"):
        pool.check_invariants(block_tables=[table])
    with pytest.raises(AssertionError, match="owners"):
        # two tables claim the same block but its refcount is 1
        pool.check_invariants(block_tables=[[table[1]], [table[1]]])


def test_churn_1k_cycles_with_shared_prefixes():
    """1k cycles interleaving plain alloc/free with prefix register /
    match / retire: refcounts never go negative (free raises), the garbage
    block is never refcounted, invariants (incl. block-table/free-list
    disjointness) hold throughout, and dropping the index drains the pool
    to exactly full — no leaked, minted, or lost blocks."""
    pool = PagedKVPool(num_blocks=17, page_size=4)
    rng = np.random.default_rng(3)
    seqs = []                                # [(blocks, registered_count)]
    for i in range(1000):
        r = rng.random()
        if r < 0.45:                         # admit: maybe map a prefix
            toks = [int(t) for t in rng.integers(0, 3, 12)]
            blocks, matched, h = pool.match_prefix(toks)
            extra = pool.alloc(pool.blocks_for(12) - len(blocks))
            if extra is None:
                if blocks:
                    pool.free(blocks)        # un-map: the admit failed
            else:
                blocks = blocks + extra
                # register any full blocks not already covered
                for bi in range(matched // 4, 3):
                    h = pool.register_prefix(h, toks[bi * 4:bi * 4 + 4],
                                             blocks[bi])
                seqs.append(blocks)
        elif seqs:                           # retire a random sequence
            pool.free(seqs.pop(int(rng.integers(len(seqs)))))
        if i % 50 == 0:
            pool.check_invariants(block_tables=seqs)
            assert GARBAGE_BLOCK not in pool._refs
    for blocks in seqs:
        pool.free(blocks)
    pool.release_prefix_cache()
    pool.check_invariants()
    assert pool.num_live == 0
    assert pool.num_free == pool.capacity
    assert pool.stats.prefix_hits > 0        # the mix actually shared
    assert pool.stats.cache_evictions > 0    # and actually reclaimed


# ---------------------------------------------------------------------------
# Scheduler (driven by a host-only harness that plays the engine's role)
# ---------------------------------------------------------------------------

def _drive(sched, *, max_ticks=2000, on_tick=None):
    """Minimal engine stand-in: executes tick plans (prefill bookkeeping,
    one fake decode token per decode row, retirement at max_new)."""
    finished = []
    for _ in range(max_ticks):
        if not sched.has_work():
            break
        plan = sched.tick()
        if plan.prefill is not None:
            seq, _, chunk = plan.prefill
            sched.note_prefill(seq, chunk)
            if not seq.prefilling:
                seq.req.out.append(0)        # last-chunk logits seed decode
        for seq in plan.decode:
            seq.req.out.append(0)
            sched.note_decode(seq)
        for seq in list(sched.running()):
            if not seq.prefilling and len(seq.req.out) >= seq.req.max_new:
                seq.req.done = True
                finished.append(seq.req)
                sched.retire(seq)
        if on_tick is not None:
            on_tick(plan)
        sched.pool.check_invariants()
    return finished


def _sched(capacity_blocks, *, page=4, batch=4, max_len=64, chunk=8,
           watermark=None):
    pool = PagedKVPool(capacity_blocks + 1, page)
    return Scheduler(pool, max_batch=batch, max_len=max_len,
                     prefill_chunk=chunk, watermark_blocks=watermark)


def test_admission_rejects_unservable_requests():
    sched = _sched(4, page=4, max_len=64)    # 16-token pool
    with pytest.raises(ValueError):          # never fits the pool
        sched.submit(Request(1, np.zeros(18, np.int32), max_new=2))
    with pytest.raises(ValueError):          # never fits the serve window
        sched.submit(Request(2, np.zeros(10, np.int32), max_new=60))


def test_admission_headroom_one_long_many_short():
    """Regression for the dense engine's ``_admit``, which admitted by free
    *slot* only: a long prompt must wait for KV head-room, not be admitted
    into a pool its prompt cannot fit, and everything still completes."""
    sched = _sched(10, page=4, batch=3, max_len=44, chunk=8)
    long_req = Request(1, np.zeros(30, np.int32), max_new=4)   # 8 blocks
    shorts = [Request(2 + i, np.zeros(6, np.int32), max_new=4)
              for i in range(5)]
    sched.submit(long_req)
    for r in shorts:
        sched.submit(r)
    admitted_at_tick1 = []

    def watch(plan):
        admitted_at_tick1.extend(s.req.rid for s in plan.admitted
                                 if sched.ticks == 1)
    finished = _drive(sched, on_tick=watch)
    # head-of-line long request needs 8(+watermark) of 10 blocks: admitted
    # alone up front, and the shorts (FIFO behind it) only after
    assert admitted_at_tick1 == [1]
    assert {r.rid for r in finished} == {r.rid for r in [long_req] + shorts}
    assert all(len(r.out) == r.max_new for r in finished)
    assert sched.stats.admission_waits > 0   # shorts actually waited


def test_fifo_admission_order():
    sched = _sched(32, page=4, batch=2, max_len=32, chunk=8)
    for i in range(6):
        sched.submit(Request(i, np.zeros(8, np.int32), max_new=3))
    order = []
    _drive(sched, on_tick=lambda p: order.extend(
        s.req.rid for s in p.admitted))
    assert order == sorted(order)


def test_preemption_evicts_youngest_and_recovers():
    """A pool too small for every admitted sequence's decode growth must
    preempt the youngest (recompute), keep invariants, and still finish
    every request with full output."""
    # 2 slots, 24-token pool; prompts 8 + max_new 14 -> ~22 tokens each:
    # both admit (watermark 0) but cannot both grow to completion
    sched = _sched(6, page=4, batch=2, max_len=24, chunk=8, watermark=0)
    reqs = [Request(i, np.zeros(8, np.int32), max_new=14) for i in range(2)]
    for r in reqs:
        sched.submit(r)
    finished = _drive(sched)
    assert sched.stats.preemptions > 0
    assert {r.rid for r in finished} == {0, 1}
    assert all(len(r.out) == 14 for r in finished)
    assert sched.pool.num_live == 0


def test_chunk_lengths_are_quantized():
    sched = _sched(32, page=4, batch=1, max_len=64, chunk=8)
    sched.submit(Request(1, np.zeros(29, np.int32), max_new=2))
    chunks = []

    def watch(plan):
        if plan.prefill is not None:
            chunks.append(plan.prefill[2])
    _drive(sched, on_tick=watch)
    assert sum(chunks) == 29                 # prompt chunked exactly, no pad
    allowed = {8, 4, 2, 1}                   # chunk + power-of-two tail
    assert set(chunks) <= allowed
    assert chunks[:3] == [8, 8, 8]


def test_scheduler_prefix_sharing_skips_resident_prefill():
    """Host-only end-to-end of the sharing policy: a second identical
    prompt maps the retired first sequence's registered blocks, prefills
    only the one un-mappable token (the decode seed), and CoWs the partial
    tail block it writes into."""
    pool = PagedKVPool(17, 4)
    sched = Scheduler(pool, max_batch=2, max_len=64, prefill_chunk=8,
                      prefix_sharing=True)
    prompt = np.arange(24, dtype=np.int32)
    sched.submit(Request(1, prompt.copy(), max_new=4))
    _drive(sched)
    tok0 = sched.stats.prefill_tokens
    assert tok0 == 24                        # leader computed everything
    assert pool.num_reclaimable == 6         # its 6 prompt blocks cached
    sched.submit(Request(2, prompt.copy(), max_new=4))
    finished = _drive(sched)
    assert len(finished) == 1 and len(finished[0].out) == 4
    # follower: 23 of 24 positions mapped, 1 computed, tail block CoW'd
    assert sched.stats.prefill_tokens - tok0 == 1
    assert pool.stats.prefix_tokens_saved == 23
    assert pool.stats.cow_copies == 1
    pool.release_prefix_cache()
    pool.check_invariants()
    assert pool.num_free == pool.capacity


def test_starvation_bound():
    """Every admitted sequence makes progress within progress_bound ticks
    under sustained mixed load (decode-priority + oldest-first prefill)."""
    sched = _sched(24, page=4, batch=3, max_len=40, chunk=8)
    rng = np.random.default_rng(2)
    for i in range(12):
        sched.submit(Request(i, np.zeros(int(rng.integers(4, 30)),
                                         np.int32),
                             max_new=int(rng.integers(2, 8))))
    bound = sched.progress_bound()
    worst = 0

    def watch(plan):
        nonlocal worst
        for seq in sched.running():
            worst = max(worst, sched.ticks - seq.last_progress)
    finished = _drive(sched, on_tick=watch)
    assert len(finished) == 12
    assert worst <= bound, (worst, bound)
