"""scripts/check_bench.py gating semantics (ISSUE 4 satellite).

Both missing directions must fail: a baseline row with no measured
counterpart (renamed/dropped/not-run benchmark), and — under ``--strict`` —
a measured row nobody added a baseline for.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))
import check_bench  # noqa: E402


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


@pytest.fixture
def files(tmp_path):
    baseline = _write(tmp_path, "baseline.json",
                      {"rows": {"cold": {"us": 1000}, "warm": {"us": 10}}})
    measured = _write(tmp_path, "measured.json",
                      {"rows": [{"name": "cold", "us": 900, "derived": ""},
                                {"name": "warm", "us": 9, "derived": ""}]})
    return baseline, measured


def test_all_rows_within_ratio_passes(files):
    baseline, measured = files
    assert check_bench.main([measured, "--baseline", baseline]) == 0


def test_regression_fails(tmp_path, files):
    baseline, _ = files
    measured = _write(tmp_path, "slow.json",
                      {"rows": [{"name": "cold", "us": 2500, "derived": ""},
                                {"name": "warm", "us": 9, "derived": ""}]})
    assert check_bench.main([measured, "--baseline", baseline]) == 1


def test_baseline_row_without_measurement_fails(tmp_path, files):
    """A renamed/dropped benchmark must not silently stop being gated."""
    baseline, _ = files
    measured = _write(tmp_path, "partial.json",
                      {"rows": [{"name": "cold", "us": 900, "derived": ""}]})
    assert check_bench.main([measured, "--baseline", baseline]) == 1


def test_measured_row_without_baseline_needs_strict(tmp_path, files):
    """--strict fails a measured-but-ungated row; default only warns not."""
    baseline, _ = files
    measured = _write(
        tmp_path, "extra.json",
        {"rows": [{"name": "cold", "us": 900, "derived": ""},
                  {"name": "warm", "us": 9, "derived": ""},
                  {"name": "brand_new_bench", "us": 5, "derived": ""}]})
    assert check_bench.main([measured, "--baseline", baseline]) == 0
    assert check_bench.main([measured, "--baseline", baseline,
                             "--strict"]) == 1


def test_explicit_key_missing_from_baseline_fails(files):
    baseline, measured = files
    assert check_bench.main([measured, "--baseline", baseline,
                             "--key", "nonexistent"]) == 1


def test_multiple_measured_files_merge(tmp_path, files):
    """The CI job measures dispatch-layer and serve-load rows into separate
    JSON files; the gate merges them (later files win collisions)."""
    baseline, _ = files
    m1 = _write(tmp_path, "m1.json",
                {"rows": [{"name": "cold", "us": 900, "derived": ""}]})
    m2 = _write(tmp_path, "m2.json",
                {"rows": [{"name": "warm", "us": 9, "derived": ""}]})
    assert check_bench.main([m1, m2, "--baseline", baseline]) == 0
    # either file alone leaves a baseline row unmeasured -> fail
    assert check_bench.main([m1, "--baseline", baseline]) == 1
    # collision: the later file's value wins (2500 would fail, 900 passes)
    m3 = _write(tmp_path, "m3.json",
                {"rows": [{"name": "cold", "us": 2500, "derived": ""}]})
    assert check_bench.main([m3, m1, m2, "--baseline", baseline]) == 0


def test_failure_names_worst_ratio_row(tmp_path, files, capsys):
    """On failure the log must name the worst-ratio row — the offender is
    visible straight from CI instead of a by-hand JSON diff."""
    baseline, _ = files
    measured = _write(tmp_path, "slow.json",
                      {"rows": [{"name": "cold", "us": 2500, "derived": ""},
                                {"name": "warm", "us": 80, "derived": ""}]})
    assert check_bench.main([measured, "--baseline", baseline]) == 1
    err = capsys.readouterr().err
    assert "[GATE WORST] warm" in err        # 8.0x beats cold's 2.5x
    # a passing run prints no worst-row line
    ok = _write(tmp_path, "ok.json",
                {"rows": [{"name": "cold", "us": 900, "derived": ""},
                          {"name": "warm", "us": 9, "derived": ""}]})
    assert check_bench.main([ok, "--baseline", baseline]) == 0
    assert "[GATE WORST]" not in capsys.readouterr().err
