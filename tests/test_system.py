"""End-to-end behaviour: training descends, resumes exactly, serves, and the
paper's central claim (optimal parameters depend on input size) is visible
through the framework's own selection machinery."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import init_model
from repro.optim import adamw, constant, warmup_cosine
from repro.runtime import TrainController, build_train_step


def _setup(arch="llama3_8b", seed=0, lr=1e-3):
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    opt = adamw(warmup_cosine(lr, 5, 200))
    state = opt.init(params)
    step = jax.jit(build_train_step(cfg, opt, microbatches=2))
    ds = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8,
                                seed=seed))
    return cfg, params, opt, state, step, ds


def test_training_loss_decreases():
    cfg, params, opt, state, step, ds = _setup()
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        params, state, m = step(params, state, batch, jnp.asarray(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


@pytest.mark.slow
def test_resume_is_bit_exact(tmp_path):
    """Crash at step 12, restore at 10, replay: final loss must equal the
    uninterrupted run (stateless data + checkpointed state => exact)."""
    def build(ckpt_dir, fault):
        cfg, params, opt, state, step, ds = _setup(seed=3)

        def run_step(st, s):
            p, o = st
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            p, o, m = step(p, o, batch, jnp.asarray(s))
            return (p, o), {"loss": float(m["loss"])}

        ctl = TrainController(run_step, CheckpointManager(str(ckpt_dir)),
                              ckpt_every=5, fault_hook=fault)
        return ctl, (params, state)

    ctl_ref, st0 = build(tmp_path / "ref", None)
    _, hist_ref = ctl_ref.run(st0, start_step=0, num_steps=15)

    fired = {"n": 0}

    def fault(step):
        if step == 12 and not fired["n"]:
            fired["n"] = 1
            raise RuntimeError("injected")

    ctl, st0b = build(tmp_path / "ft", fault)
    _, hist = ctl.run(st0b, start_step=0, num_steps=15)
    assert fired["n"] == 1
    final_ref = [h for h in hist_ref if h["step"] == 14][-1]["loss"]
    final_ft = [h for h in hist if h["step"] == 14][-1]["loss"]
    np.testing.assert_allclose(final_ft, final_ref, rtol=1e-6)


@pytest.mark.slow
def test_paper_claim_params_depend_on_input_size():
    """Table 1's headline: the best block parameters shift with input size.
    We assert the framework *can* express this: the offline selector returns
    size-dependent choices under a constrained machine."""
    from repro.core import MachineDescription, best_variant
    from repro.kernels.matmul import FAMILY

    tiny_vmem = MachineDescription(
        name="tiny", vmem_bytes=1 << 19, vreg_budget=512, num_cores=8,
        sublane=8, lane=128, mxu=128, hbm_bytes=1 << 30, hbm_bw=1e11,
        peak_flops_bf16=1e12, ici_bw=1e10)
    small = best_variant(FAMILY, tiny_vmem, {"M": 256, "N": 256, "K": 256})
    large = best_variant(FAMILY, tiny_vmem, {"M": 8192, "N": 8192, "K": 8192})
    # feasibility: each candidate satisfies the family's own VMEM counter
    # under its leaf's plan (cached and uncached leaves differ)
    for cand in (small, large):
        num, den = FAMILY.counter_value(cand.plan, "vmem_bytes")
        vmem = float(num.eval(cand.assignment)) / float(
            den.eval(cand.assignment) or 1)
        assert vmem <= (1 << 19), (cand.describe(), vmem)
    # size-dependence: the occupancy-driven score reshuffles the choice
    assert small.assignment != large.assignment or \
        small.leaf_index != large.leaf_index


@pytest.mark.slow
def test_quickstart_example_runs():
    import subprocess, sys
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "quickstart.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
