"""DispatchCache under concurrency: determinism, stats accounting, and
frozen-plan safety (ISSUE 4 satellite).

N threads resolving an overlapping triple set through ONE shared cache —
with triples landing in different tiers (memory LRU, disk artifact, cold
rebuild) — must all see byte-identical candidates, and the locked-tier
stats must sum exactly to the number of resolutions.  The frozen-plan read
path must stay safe while another thread keeps republishing plans.
"""
import json
import threading

import pytest

from repro.artifacts import ArtifactStore, DispatchCache, compile_family
from repro.artifacts.dispatch import set_default_cache
from repro.core import TPU_V5E, best_variant
from repro.kernels.ops import FAMILIES

MATMUL = FAMILIES["matmul"]
MATADD = FAMILIES["matadd"]

#: Overlapping triple set spanning tiers once a store holds the first two.
TRIPLES = [
    (MATMUL, {"M": 512, "N": 512, "K": 512}),      # disk (compiled below)
    (MATMUL, {"M": 500, "N": 500, "K": 500}),      # disk, off-grid revalidate
    (MATMUL, {"M": 320, "N": 320, "K": 320}),      # cold
    (MATADD, {"M": 512, "N": 512}),                # cold (family w/o table)
]
N_THREADS = 8
ROUNDS = 12


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


def _candidate_bytes(cand):
    """Canonical byte form — 'byte-identical' means identical here."""
    return json.dumps({"leaf": cand.leaf_index,
                       "assignment": dict(sorted(cand.assignment.items())),
                       "flags": dict(sorted(cand.plan.flags.items())),
                       "score": repr(cand.score)}, sort_keys=True).encode()


def _run_threads(worker, n=N_THREADS):
    errors = []

    def guarded(i):
        try:
            worker(i)
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=guarded, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors


def test_concurrent_resolution_deterministic_and_accounted(tmp_path):
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E],
                   shapes=[dict(TRIPLES[0][1]), dict(TRIPLES[1][1])])
    cache = DispatchCache(store=store)
    results = [[] for _ in range(N_THREADS)]

    def worker(i):
        # stagger the walk so threads collide on different triples
        order = TRIPLES[i % len(TRIPLES):] + TRIPLES[:i % len(TRIPLES)]
        for _ in range(ROUNDS):
            for fam, data in order:
                results[i].append(_candidate_bytes(
                    cache.best_variant(fam, TPU_V5E, data)))

    _run_threads(worker)

    # byte-identical candidates across every thread, per triple position
    for i in range(1, N_THREADS):
        mine = sorted(results[i])
        assert mine == sorted(results[0])
    # ... and identical to the single-threaded cold reference
    ref = {id(t): _candidate_bytes(
        best_variant(t[0], TPU_V5E, t[1], use_cache=False))
        for t in TRIPLES}
    assert set(results[0]) == set(ref.values())

    # locked-tier accounting: every resolution bumped exactly one counter
    total_calls = N_THREADS * ROUNDS * len(TRIPLES)
    s = cache.stats
    assert s.memory_hits + s.disk_hits + s.cold_builds == total_calls
    assert s.frozen_hits == 0                      # nothing frozen here
    assert s.disk_hits >= 2 and s.cold_builds >= 2
    assert s.measured_hits == 0                    # untuned table
    assert sum(v for k, v in s.as_dict().items()
               if k in ("memory_hits", "disk_hits", "cold_builds")) \
        == total_calls


def test_warm_readers_safe_under_monitor_hot_swap():
    """ISSUE 8 satellite: N threads reading ``warm_callable``/
    ``best_variant`` while the adaptive monitor keeps re-freezing the
    triple.  Every read must see exactly the old OR the new candidate
    (byte-identical to one of the two, never torn), the callable must
    never be None, and the locked-tier stats must not move at all — swap
    publishes and frozen reads never touch the locked tiers."""
    from repro.core.select import rank_candidates
    from repro.runtime.monitor import KernelMonitor, cand_key

    cache = DispatchCache()
    fam, data = TRIPLES[2]                           # cold-resolvable triple
    ranked = rank_candidates(fam, TPU_V5E, data)
    a, b = ranked[0], ranked[1]
    cache.freeze_resolved([(fam, TPU_V5E, data, a, "symbolic")])
    legal = {_candidate_bytes(a), _candidate_bytes(b)}
    locked_before = (cache.stats.memory_hits + cache.stats.disk_hits
                     + cache.stats.cold_builds)

    skew = {cand_key(a): 8e-3, cand_key(b): 1e-3}    # incumbent a looks slow

    def timer(family, plan, assignment, d, cfg):
        key = tuple(sorted((k, int(v)) for k, v in assignment.items()))
        for (_, asg), secs in skew.items():
            if asg == key:
                return [secs]
        return [4e-3]

    mon = KernelMonitor(cache, machine=TPU_V5E, window=1, patience=1,
                        probe_every=1, top_k=2, timer=timer, seed=0)
    mon.track(fam, data)
    stop = threading.Event()

    def swapper(_):
        t, seen = 0, 0
        while not stop.is_set():
            mon.on_tick(t)
            t += 1
            if mon.stats.swaps > seen:
                seen = mon.stats.swaps
                # flip the skew so the freshly-installed pick immediately
                # looks wrong again: the monitor keeps re-freezing
                cur = cache.frozen_entry(fam.name, TPU_V5E.name, data)
                other = b if cand_key(cur.candidate) == cand_key(a) else a
                skew[cand_key(cur.candidate)] = 8e-3
                skew[cand_key(other)] = 1e-3
                for st_ in mon._triples.values():
                    st_.reservoirs.clear()           # drop stale evidence

    def reader(i):
        try:
            for _ in range(ROUNDS * 8):
                ent = cache.frozen_entry(fam.name, TPU_V5E.name, data)
                assert ent is not None
                assert _candidate_bytes(ent.candidate) in legal
                cand = cache.best_variant(fam, TPU_V5E, data)
                assert _candidate_bytes(cand) in legal
                fn = cache.warm_callable(fam, TPU_V5E,
                                         tuple(data.items()), True)
                assert fn is not None
        finally:
            stop.set()

    errors = []

    def guarded(fn, i):
        try:
            fn(i)
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)
            stop.set()

    threads = [threading.Thread(target=guarded, args=(swapper, 0))]
    threads += [threading.Thread(target=guarded, args=(reader, i))
                for i in range(N_THREADS - 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    # the monitor really swapped (usually many times), every swap was both
    # counted and evented, and the final pick is one of the two candidates
    assert mon.stats.swaps >= 1
    assert len(mon.events) == mon.stats.swaps
    final = cache.frozen_entry(fam.name, TPU_V5E.name, data)
    assert _candidate_bytes(final.candidate) in legal
    # exact stat sums: frozen reads + swap publishes bypass the locked
    # tiers entirely — best_variant served every read from tier 0
    locked_after = (cache.stats.memory_hits + cache.stats.disk_hits
                    + cache.stats.cold_builds)
    assert locked_after == locked_before
    assert cache.stats.frozen_hits > 0


def test_frozen_read_path_safe_under_concurrent_freeze(tmp_path):
    """Readers racing freeze()/unfreeze() republications never crash, never
    see a torn plan, and always get the reference candidate."""
    store = ArtifactStore(tmp_path)
    compile_family(MATMUL, store, machines=[TPU_V5E],
                   shapes=[dict(TRIPLES[0][1])])
    cache = DispatchCache(store=store)
    ref = {i: best_variant(t[0], TPU_V5E, t[1], use_cache=False)
           for i, t in enumerate(TRIPLES)}
    stop = threading.Event()

    def freezer(_):
        grow = []
        while not stop.is_set():
            for fam, data in TRIPLES:
                grow.append((fam, TPU_V5E, data))
                cache.freeze(list(grow))
            cache.unfreeze()

    def reader(i):
        try:
            for _ in range(ROUNDS * 4):
                for j, (fam, data) in enumerate(TRIPLES):
                    cand = cache.best_variant(fam, TPU_V5E, data)
                    assert _candidate_bytes(cand) == _candidate_bytes(ref[j])
                    fn = cache.warm_callable(fam, TPU_V5E,
                                             tuple(data.items()), True)
                    assert fn is not None
        finally:
            stop.set()

    errors = []

    def guarded(fn, i):
        try:
            fn(i)
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)
            stop.set()

    threads = [threading.Thread(target=guarded, args=(freezer, 0))]
    threads += [threading.Thread(target=guarded, args=(reader, i))
                for i in range(N_THREADS - 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
