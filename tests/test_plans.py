"""repro.plans: traced warm-sets + portable serve-plan artifacts (ISSUE 5).

Acceptance properties:

- traced warm-sets are config-faithful: superset of the legacy hand list
  for llama3_8b, ``ssd_scan`` present for mamba2_130m, router/expert
  matmul shapes present for the MoE configs, encoder shapes for whisper;
- the DispatchCache recording mode captures exactly the requests the
  dispatch layer sees (trace fidelity: recorded == traced);
- serve-plan serde is byte-deterministic across two builds of the same
  (config, machine); stale ``PLAN_FORMAT_VERSION`` and mangled payloads
  read as a miss (fall back to online warm-up), never an error;
- frozen parity: a plan-backed freeze answers identically to an online
  freeze, and a ``ServeEngine`` started from a shipped plan performs zero
  cold resolutions (``DispatchCache.stats.cold_builds == 0``).
"""
import json

import pytest

from repro.artifacts import ArtifactStore, DispatchCache, compile_family
from repro.artifacts.dispatch import get_default_cache, set_default_cache
from repro.configs import get_config, get_smoke_config
from repro.core import TPU_V5E
from repro.kernels.ops import FAMILIES
from repro.plans import (PLAN_FORMAT_VERSION, PlanStore, StalePlanError,
                         StalePlanWarning, apply_serve_plan,
                         build_serve_plan, load_serve_plan, op_label,
                         plan_staleness, record_warm_set, table_digest,
                         trace_warm_set, warm_from_plan)
from repro.plans import serde as plan_serde


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)


def _triples(ops):
    return {(op.family, op.data) for op in ops}


# ---------------------------------------------------------------------------
# Trace: config-faithful warm sets
# ---------------------------------------------------------------------------

def test_traced_superset_of_legacy_hand_list_llama3():
    """The tracer must cover everything PR 4's hand list warmed."""
    cfg = get_config("llama3_8b")
    max_len = 512
    d, hd = cfg.d_model, cfg.hd
    legacy = set()
    for sq in {max_len, 2 * max_len}:
        legacy.add(("flash_attention", (("HD", hd), ("SQ", sq))))
    for m, n, k in ((max_len, cfg.d_ff or 4 * d, d),
                    (max_len, d, cfg.d_ff or 4 * d),
                    (max_len, cfg.heads * hd, d)):
        legacy.add(("matmul", (("K", k), ("M", m), ("N", n))))
    traced = _triples(trace_warm_set(cfg, max_len=max_len))
    assert legacy <= traced


def test_traced_includes_ssd_scan_for_mamba2():
    """The hand-list coverage bug: Mamba configs must warm ssd_scan."""
    cfg = get_config("mamba2_130m")
    traced = trace_warm_set(cfg, max_len=512)
    fams = {op.family for op in traced}
    assert "ssd_scan" in fams
    assert "flash_attention" not in fams          # attention-free arch
    s = cfg.ssm
    assert ("ssd_scan", (("HD", s.head_dim), ("SQ", 512),
                         ("STATE", s.state))) in _triples(traced)
    # SSM projections are matmuls the hand list never warmed
    assert ("matmul", (("K", cfg.d_model), ("M", 512),
                       ("N", s.heads * s.head_dim))) in _triples(traced)


def test_traced_includes_hybrid_both_cores():
    traced = trace_warm_set(get_config("hymba_1p5b"), max_len=512)
    fams = {op.family for op in traced}
    assert {"flash_attention", "ssd_scan", "matmul"} <= fams


@pytest.mark.parametrize("arch", ["kimi_k2_1t_a32b", "llama4_scout_17b_a16e"])
def test_traced_includes_moe_router_and_expert_shapes(arch):
    cfg = get_config(arch)
    traced = _triples(trace_warm_set(cfg, max_len=512))
    m, d = cfg.moe, cfg.d_model
    assert ("matmul", (("K", d), ("M", 512),
                       ("N", m.num_experts))) in traced   # router
    expert_n = {n for f, items in traced if f == "matmul"
                for k, n in items if k == "N"}
    expert_k = {v for f, items in traced if f == "matmul"
                for k, v in items if k == "K"}
    assert m.d_ff_expert in expert_n               # expert up-projection
    assert m.d_ff_expert in expert_k               # expert down-projection


def test_traced_includes_whisper_encoder_shapes():
    cfg = get_config("whisper_large_v3")
    traced = _triples(trace_warm_set(cfg, max_len=512))
    S, d, hd = cfg.encoder.seq_len, cfg.d_model, cfg.hd
    assert ("flash_attention", (("HD", hd), ("SQ", S))) in traced
    # encoder blocks are full attention blocks: their projections run at
    # the frame width (also the decoder cross-attention K/V projections)
    for n, k in ((cfg.heads * hd, d),          # q proj
                 (cfg.kv_heads * hd, d),       # kv proj / cross-attn K,V
                 (d, cfg.heads * hd),          # out proj
                 (cfg.d_ff, d), (d, cfg.d_ff)):
        assert ("matmul", (("K", k), ("M", S), ("N", n))) in traced


def test_trace_is_deterministic_and_deduplicated():
    cfg = get_config("llama3_8b")
    a = trace_warm_set(cfg, max_len=256)
    b = trace_warm_set(cfg, max_len=256)
    assert a == b
    assert len(_triples(a)) == len(a)              # no duplicate triples
    # shared shapes merge their call sites instead of duplicating
    qo = [op for op in a if "serve.attn.q_proj" in op.sites]
    assert qo and "serve.attn.out_proj" in qo[0].sites


def test_paged_trace_rounds_attention_to_block_grid():
    """page_size > 0 (the paged serving engine) folds the KV block size
    into the attention-core bucket keys: the gather extent is the block
    grid, so a non-aligned serve window rounds up; projections keep the
    token-parallel width; an aligned window traces identically to dense."""
    cfg = get_config("llama3_8b")
    hd, d = cfg.hd, cfg.d_model
    paged = _triples(trace_warm_set(cfg, max_len=40, page_size=16))
    assert ("flash_attention", (("HD", hd), ("SQ", 48))) in paged
    assert ("flash_attention", (("HD", hd), ("SQ", 96))) in paged
    assert not any(f == "flash_attention" and ("SQ", 40) in items
                   for f, items in paged)
    assert ("matmul", (("K", d), ("M", 40),
                       ("N", cfg.heads * hd))) in paged   # q_proj unrounded
    # on-grid window: byte-identical to the dense trace
    assert trace_warm_set(cfg, max_len=128, page_size=16) == \
        trace_warm_set(cfg, max_len=128)


def test_trace_include_train_adds_train_shapes():
    cfg = get_config("llama3_8b")
    serve_only = _triples(trace_warm_set(cfg, max_len=256))
    with_train = trace_warm_set(cfg, max_len=256, include_train=True,
                                train_seq=4096, train_batch=8)
    assert serve_only < _triples(with_train)
    assert any(s.startswith("train.") for op in with_train for s in op.sites)


# ---------------------------------------------------------------------------
# DispatchCache recording mode
# ---------------------------------------------------------------------------

def test_record_mode_captures_ops_requests():
    """Requests through both counted entry points (best_variant and the
    ops-layer warm_callable) land in the record, normalized and deduped;
    outside the context nothing is recorded."""
    import jax
    from repro.kernels import ops
    cache = DispatchCache()
    set_default_cache(cache)
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    with cache.record() as rec:
        ops.matmul(a, a, impl="pallas", interpret=True)
        ops.matmul(a, a, impl="pallas", interpret=True)   # dedup, count=2
        cache.best_variant(FAMILIES["matadd"], TPU_V5E,
                           {"M": 256, "N": 256})
    key_mm = ("matmul", TPU_V5E.name,
              (("K", 128), ("M", 128), ("N", 128)))
    assert rec.requests[0] == key_mm
    assert rec.counts[key_mm] == 2
    assert len(rec) == 2
    triples = rec.triples()
    assert triples[1] == ("matadd", TPU_V5E.name, {"M": 256, "N": 256})
    # recording stopped at context exit
    cache.best_variant(FAMILIES["matadd"], TPU_V5E, {"M": 512, "N": 512})
    assert len(rec) == 2


def test_record_warm_set_matches_trace():
    """Trace fidelity: replaying the traced requests through the live
    dispatch layer records exactly the traced triples, in order."""
    cfg = get_smoke_config("llama3_8b")
    cache = DispatchCache()
    recorded = record_warm_set(cfg, machine=TPU_V5E, cache=cache,
                               max_len=128)
    traced = trace_warm_set(cfg, max_len=128)
    assert [(op.family, op.data) for op in recorded] == \
           [(op.family, op.data) for op in traced]
    assert len(cache) == len(traced)               # LRU warmed as a side effect


# ---------------------------------------------------------------------------
# Serde + store: byte determinism, version policy
# ---------------------------------------------------------------------------

def test_plan_bytes_deterministic_across_builds(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    plan_a, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    plan_b, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    assert plan_serde.dumps(plan_a) == plan_serde.dumps(plan_b)
    assert plan_a.digest() == plan_b.digest()
    pa = PlanStore(tmp_path / "a").save_plan(plan_a)
    pb = PlanStore(tmp_path / "b").save_plan(plan_b)
    assert pa.read_bytes() == pb.read_bytes()


def test_plan_roundtrip_preserves_entries(tmp_path):
    cfg = get_smoke_config("mamba2_130m")
    plan, dropped = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    assert not dropped
    assert any(e.family == "ssd_scan" for e in plan.entries)
    store = PlanStore(tmp_path)
    store.save_plan(plan)
    loaded = store.load_plan(cfg.name, TPU_V5E.name)
    assert loaded == plan
    for e in loaded.entries:
        assert e.label == op_label(e.family, e.data_dict())
        assert e.rank_source in ("measured", "symbolic", "cold")


def test_stale_plan_format_version_is_a_miss(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    store = PlanStore(tmp_path)
    path = store.save_plan(plan)
    payload = json.loads(path.read_text())
    payload["format"] = PLAN_FORMAT_VERSION + 1
    path.write_text(json.dumps(payload))
    assert store.load_plan(cfg.name, TPU_V5E.name) is None
    # and the engine-level warm-up falls back to ONLINE warm-up, not an error
    cache = DispatchCache()
    assert warm_from_plan(cfg, max_len=128, store=store, cache=cache) is None


@pytest.mark.parametrize("mangle", ["not-json", "kind", "entries",
                                    "assignment", "rank_source"])
def test_mangled_plan_payload_is_a_miss(tmp_path, mangle):
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    store = PlanStore(tmp_path)
    path = store.save_plan(plan)
    if mangle == "not-json":
        path.write_text("{truncated")
    else:
        payload = json.loads(path.read_text())
        if mangle == "kind":
            payload["kind"] = "dispatch"
        elif mangle == "entries":
            payload["entries"] = "nope"
        elif mangle == "assignment":
            payload["entries"][0]["candidate"]["assignment"] = {"bm": "x"}
        elif mangle == "rank_source":
            payload["entries"][0]["rank_source"] = "vibes"
        path.write_text(json.dumps(payload))
    assert store.load_plan(cfg.name, TPU_V5E.name) is None


def test_machine_bindings_mismatch_is_a_miss(tmp_path):
    """A plan built for a differently-specced host must not be applied."""
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    tampered = plan_serde.ServePlan(
        config=plan.config, machine=plan.machine,
        machine_bindings={**plan.machine_bindings, "V": 1},
        max_len=plan.max_len, page_size=plan.page_size,
        include_train=plan.include_train,
        entries=plan.entries)
    store = PlanStore(tmp_path)
    store.save_plan(tampered)
    assert load_serve_plan(cfg, store=store) is None
    assert warm_from_plan(cfg, max_len=128, store=store,
                          cache=DispatchCache()) is None


def test_max_len_mismatch_is_a_miss(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    store = PlanStore(tmp_path)
    store.save_plan(plan)
    assert load_serve_plan(cfg, store=store, max_len=128) is not None
    assert load_serve_plan(cfg, store=store, max_len=256) is None


def test_page_size_mismatch_is_a_miss(tmp_path):
    """A plan traced for one paged block size (or the dense layout) must
    not warm an engine running another: the attention bucket keys differ
    off the block grid, and the plan identity keeps them apart even when
    the traces happen to coincide."""
    cfg = get_smoke_config("llama3_8b")
    store = PlanStore(tmp_path)
    dense_plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    store.save_plan(dense_plan)
    assert load_serve_plan(cfg, store=store, page_size=0) is not None
    assert load_serve_plan(cfg, store=store, page_size=16) is None
    assert warm_from_plan(cfg, max_len=128, page_size=16, store=store,
                          cache=DispatchCache()) is None
    paged_plan, _ = build_serve_plan(cfg, max_len=128, page_size=16,
                                     cache=DispatchCache())
    store.save_plan(paged_plan)                 # same (config, machine) file
    assert load_serve_plan(cfg, store=store, page_size=16) is not None
    picks = warm_from_plan(cfg, max_len=128, page_size=16, store=store,
                           cache=DispatchCache())
    assert picks is not None and len(picks) == len(paged_plan.entries)


def test_unknown_family_in_plan_is_a_miss_and_publishes_nothing(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    bad_entry = plan_serde.PlanEntry(
        label="bogus@X1", family="bogus_family", data=(("X", 1),),
        sites=("serve.bogus",), candidate=plan.entries[0].candidate,
        rank_source="cold")
    tampered = plan_serde.ServePlan(
        config=plan.config, machine=plan.machine,
        machine_bindings=plan.machine_bindings, max_len=plan.max_len,
        page_size=plan.page_size, include_train=plan.include_train,
        entries=plan.entries + (bad_entry,))
    cache = DispatchCache()
    assert apply_serve_plan(tampered, cache=cache) is None
    assert cache.frozen_plan is None               # nothing half-published


# ---------------------------------------------------------------------------
# Staleness digests (PLAN_FORMAT_VERSION 3, ISSUE 8)
# ---------------------------------------------------------------------------

def _compiled_store(tmp_path, shapes=({"M": 512, "N": 512, "K": 512},)):
    store = ArtifactStore(tmp_path)
    compile_family(FAMILIES["matmul"], store, machines=[TPU_V5E],
                   shapes=[dict(s) for s in shapes])
    return store


def _retune(store):
    """Simulate scripts/tune_artifacts.py rewriting a dispatch table in
    place: any payload change (here, a re-ranked score) changes the
    canonical digest."""
    payload = store.load_dispatch("matmul", TPU_V5E.name)
    assert payload is not None
    bucket = next(iter(payload["buckets"]))
    payload["buckets"][bucket][0]["score"] = 123.456
    store.save_dispatch(payload)


def test_v3_plan_records_table_digests_and_roundtrips(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    store = _compiled_store(tmp_path)
    plan, _ = build_serve_plan(cfg, max_len=128,
                               cache=DispatchCache(store=store))
    dm = plan.table_digest_map()
    fams = {e.family for e in plan.entries}
    assert set(dm) == fams                         # one digest per family
    assert dm["matmul"] == table_digest(store, "matmul", TPU_V5E.name) != ""
    # families with no compiled table record the empty digest
    assert [f for f in dm if dm[f] == ""] == sorted(fams - {"matmul"})
    pstore = PlanStore(tmp_path)
    pstore.save_plan(plan)
    loaded = pstore.load_plan(cfg.name, TPU_V5E.name)
    assert loaded == plan and loaded.table_digests == plan.table_digests
    # storeless build: every digest empty, still a valid v3 plan
    bare, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    assert set(bare.table_digest_map().values()) == {""}


def test_plan_staleness_detects_retuned_table(tmp_path):
    cfg = get_smoke_config("llama3_8b")
    store = _compiled_store(tmp_path)
    plan, _ = build_serve_plan(cfg, max_len=128,
                               cache=DispatchCache(store=store))
    assert plan_staleness(plan, store=store) == {}  # fresh
    recorded = plan.table_digest_map()["matmul"]
    _retune(store)
    stale = plan_staleness(plan, store=store)
    assert set(stale) == {"matmul"}
    rec, cur = stale["matmul"]
    assert rec == recorded and cur != recorded and cur != ""


def test_stale_digest_warns_by_default_and_refuses_strict(tmp_path):
    """The tentpole contract: a retuned table under a shipped plan warns
    (and falls back to online warm-up) by default, refuses under strict —
    and a fresh plan keeps loading silently either way."""
    cfg = get_smoke_config("llama3_8b")
    store = _compiled_store(tmp_path)
    cache = DispatchCache(store=store)
    plan, _ = build_serve_plan(cfg, max_len=128, cache=cache)
    pstore = PlanStore(tmp_path)
    pstore.save_plan(plan)

    # fresh: both modes load the plan, no staleness warning
    import warnings as _w
    for strict in (False, True):
        with _w.catch_warnings(record=True) as rec:
            _w.simplefilter("always")
            picks = warm_from_plan(cfg, max_len=128, store=pstore,
                                   cache=DispatchCache(store=store),
                                   strict=strict)
        assert picks is not None
        assert not [w for w in rec
                    if issubclass(w.category, StalePlanWarning)]

    _retune(store)
    with pytest.warns(StalePlanWarning, match="STALE.*matmul"):
        assert warm_from_plan(cfg, max_len=128, store=pstore,
                              cache=DispatchCache(store=store)) is None
    with pytest.raises(StalePlanError, match="plan_artifacts"):
        warm_from_plan(cfg, max_len=128, store=pstore,
                       cache=DispatchCache(store=store), strict=True)


def test_engine_start_warns_then_falls_back_online_on_stale_plan(tmp_path):
    """warm_kernel_dispatch: the warn path still warms (online), the
    strict path raises before touching any tier — the CLI's
    --strict-plans wiring sits directly on top of this."""
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("llama3_8b")
    store = _compiled_store(tmp_path)
    cache = DispatchCache(store=store)
    plan, _ = build_serve_plan(cfg, max_len=128, cache=cache)
    pstore = PlanStore(tmp_path)
    pstore.save_plan(plan)
    _retune(store)

    warm_cache = DispatchCache(store=store)
    set_default_cache(warm_cache)
    with pytest.warns(StalePlanWarning):
        picks = warm_kernel_dispatch(cfg, max_len=128, plan_store=pstore)
    assert picks                                    # online fallback warmed
    assert warm_cache.frozen_plan is not None

    set_default_cache(DispatchCache(store=store))
    with pytest.raises(StalePlanError):
        warm_kernel_dispatch(cfg, max_len=128, plan_store=pstore,
                             strict_plans=True)


def test_v2_plan_payload_is_a_miss_never_an_error(tmp_path):
    """A pre-digest (v2) plan has no table_digests: the version check must
    read it as a silent miss — even under strict, which only governs
    *loaded* plans."""
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    pstore = PlanStore(tmp_path)
    path = pstore.save_plan(plan)
    payload = json.loads(path.read_text())
    payload["format"] = 2
    del payload["table_digests"]                    # v2 schema had none
    path.write_text(json.dumps(payload))
    assert pstore.load_plan(cfg.name, TPU_V5E.name) is None
    for strict in (False, True):
        assert warm_from_plan(cfg, max_len=128, store=pstore,
                              cache=DispatchCache(), strict=strict) is None


def test_plan_artifacts_cli_check_mode(tmp_path, capsys):
    """scripts/plan_artifacts.py --check: FRESH exits 0; STALE exits 0 in
    warn mode and 1 under --strict (the CI stale-plan contract)."""
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "plan_artifacts_check", os.path.join(os.path.dirname(__file__), "..",
                                             "scripts", "plan_artifacts.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    base = ["--config", "llama3_8b", "--smoke", "--machine", "tpu_v5e",
            "--max-len", "128", "--out", str(tmp_path)]

    assert mod.main(base) == 0                      # build (digests all "")
    capsys.readouterr()
    assert mod.main(base + ["--check"]) == 0
    assert "[FRESH]" in capsys.readouterr().out

    # a table appearing where none existed is also drift: the plan's picks
    # were resolved without it
    _compiled_store(tmp_path)
    assert mod.main(base + ["--check"]) == 0        # warn mode exits 0
    assert "[STALE]" in capsys.readouterr().out
    assert mod.main(base + ["--check", "--strict"]) == 1
    out = capsys.readouterr()
    assert "[STALE]" in out.out and "stale plan(s)" in out.err


# ---------------------------------------------------------------------------
# Plan-backed freeze: zero cold resolutions + parity with online warm-up
# ---------------------------------------------------------------------------

def test_plan_backed_freeze_zero_cold_and_parity(tmp_path):
    """Acceptance: a plan-backed start performs zero cold resolutions and
    answers every warm-set triple identically to an online freeze."""
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("llama3_8b")
    plan, _ = build_serve_plan(cfg, max_len=128, cache=DispatchCache())
    store = PlanStore(tmp_path)
    store.save_plan(plan)

    online_cache = DispatchCache()
    set_default_cache(online_cache)
    online_picks = warm_kernel_dispatch(cfg, max_len=128, plan_store=False)
    assert online_cache.stats.cold_builds > 0      # the cost the plan removes

    plan_cache = DispatchCache()
    set_default_cache(plan_cache)
    picks = warm_kernel_dispatch(cfg, max_len=128, plan_store=store)
    assert plan_cache.stats.cold_builds == 0
    assert plan_cache.stats.disk_hits == 0 and plan_cache.stats.memory_hits == 0
    assert picks.keys() == online_picks.keys()
    for label in picks:
        assert picks[label]["candidate"] == online_picks[label]["candidate"]
    # the frozen plans resolve identically too
    for op in trace_warm_set(cfg, max_len=128):
        a = plan_cache.frozen_entry(op.family, TPU_V5E.name, op.data_dict())
        b = online_cache.frozen_entry(op.family, TPU_V5E.name, op.data_dict())
        assert a is not None and b is not None
        assert a.candidate == b.candidate
    # steady-state dispatch through the plan-backed cache stays cold-free
    for op in trace_warm_set(cfg, max_len=128):
        plan_cache.best_variant(FAMILIES[op.family], TPU_V5E, op.data_dict())
    assert plan_cache.stats.cold_builds == 0


def test_serve_engine_starts_from_shipped_plan(tmp_path):
    """Acceptance at the engine level: ServeEngine(warm_kernels=True) with a
    shipped plan artifact pins every pick without a single cold build."""
    import jax
    from repro.models import init_model
    from repro.runtime import ServeEngine
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("llama3_8b")
    # built for the engine's paged block size — the plan identity carries
    # page_size, so a dense-traced plan would (correctly) read as a miss
    plan, _ = build_serve_plan(cfg, max_len=128, page_size=16,
                               cache=DispatchCache())
    store = PlanStore(tmp_path)
    store.save_plan(plan)

    online_cache = DispatchCache()
    set_default_cache(online_cache)
    online_picks = warm_kernel_dispatch(cfg, max_len=128, page_size=16,
                                        plan_store=False)

    cache = DispatchCache()
    set_default_cache(cache)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=128, page_size=16,
                      warm_kernels=True, plan_store=store)
    assert cache.stats.cold_builds == 0
    assert eng.kernel_plan.keys() == online_picks.keys()
    for label, info in eng.kernel_plan.items():
        assert info["candidate"] == online_picks[label]["candidate"]
    assert len(cache.frozen_plan) == len(eng.kernel_plan)

    # prefix sharing + async overlap add no shapes to the warm set (CoW is
    # a scalar-indexed cache update, mapped prefills hit the same quantized
    # chunk widths): serving a shared-prefix workload through the shipped
    # plan stays at zero cold builds
    import numpy as np
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=128, page_size=16,
                       prefix_sharing=True, async_depth=2,
                       warm_kernels=True, plan_store=store)
    rng = np.random.default_rng(0)
    lead = rng.integers(0, cfg.vocab, 40)
    eng2.submit(lead, max_new=4)
    eng2.run_until_drained()
    eng2.submit(np.concatenate([lead[:32],
                                rng.integers(0, cfg.vocab, 6)]), max_new=4)
    eng2.run_until_drained()
    assert eng2.pool.stats.prefix_hits > 0
    assert cache.stats.cold_builds == 0


def test_warm_kernel_dispatch_falls_back_online_without_plan(tmp_path):
    """No plan artifact (or plan_store=False): traced online warm-up, and
    Mamba's ssd_scan is now part of it (the hand-list fix end to end)."""
    from repro.runtime.serving import warm_kernel_dispatch
    cfg = get_smoke_config("mamba2_130m")
    cache = DispatchCache()
    set_default_cache(cache)
    picks = warm_kernel_dispatch(cfg, max_len=128,
                                 plan_store=PlanStore(tmp_path))  # empty dir
    assert any(label.startswith("ssd_scan@") for label in picks)
    assert cache.stats.cold_builds > 0
    assert cache.frozen_plan is not None and len(cache.frozen_plan) == \
        len(picks)


# ---------------------------------------------------------------------------
# CLI smoke (the CI plan-build contract)
# ---------------------------------------------------------------------------

def test_plan_artifacts_cli_dry_run_and_build(tmp_path, capsys):
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "plan_artifacts", os.path.join(os.path.dirname(__file__), "..",
                                       "scripts", "plan_artifacts.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    rc = mod.main(["--config", "llama3_8b", "--smoke", "--machine",
                   "tpu_v5e", "--max-len", "128", "--dry-run"])
    out = capsys.readouterr().out
    assert rc == 0 and "[dry-run]" in out and "traced triples" in out
    assert not (tmp_path / "plans").exists()

    rc = mod.main(["--config", "llama3_8b", "--smoke", "--machine",
                   "tpu_v5e", "--max-len", "128", "--out", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0 and "[OK]" in out
    cfg = get_smoke_config("llama3_8b")
    loaded = PlanStore(tmp_path).load_plan(cfg.name, TPU_V5E.name)
    assert loaded is not None and len(loaded.entries) > 0
