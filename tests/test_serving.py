"""Serving engine: continuous batching must equal per-request greedy decode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import decode_step, forward, init_cache, init_model, prefill
from repro.runtime import ServeEngine


def _reference_greedy(cfg, params, prompt, max_new):
    """Straight full-forward greedy decode (no cache) — slow oracle."""
    cur = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(max_new):
        logits, _ = forward(params, cfg, cur)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_130m", "hymba_1p5b"])
def test_engine_matches_reference(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(5)]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    done = {r.rid: r for r in eng.run_until_drained()}
    assert set(done) == set(rids)
    for rid, prompt in zip(rids, prompts):
        want = _reference_greedy(cfg, params, prompt, 6)
        got = done[rid].out[:6]
        # bf16 accumulation differences can flip near-tie argmax very rarely;
        # require exact match on the first tokens and >= 4/6 overall
        assert got[0] == want[0], (arch, got, want)
        agree = sum(g == w for g, w in zip(got, want))
        assert agree >= 4, (arch, got, want)


def test_continuous_batching_slot_reuse():
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(1)
    n = 7                                  # > max_batch: forces slot reuse
    for i in range(n):
        eng.submit(rng.integers(0, cfg.vocab, 5 + i), max_new=4)
    done = eng.run_until_drained()
    assert len(done) == n
    assert all(len(r.out) >= 4 for r in done)


def test_eos_stops_early():
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=48)
    # discover the greedy first token, then use it as EOS
    probe = eng.submit(np.arange(6), max_new=1)
    first = eng.run_until_drained()[0].out[0]
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=48)
    rid = eng2.submit(np.arange(6), max_new=16, eos=first)
    done = eng2.run_until_drained()
    assert len(done[0].out) == 1          # stopped at eos immediately
