"""Compiled-evaluation subsystem: batch evaluators vs the exact core.

CompiledPoly must agree with exact Fraction evaluation wherever its
magnitude certificate claims exactness (and fall back where it cannot);
CompiledSystem.feasible_rows must reproduce, row for row, the INCONSISTENT
verdicts of the reference ``subs(asg).check()`` path.  Also covers the two
constraint-solver fixes that ride with the compiled core: exact integer
tightening of strict bounds and unbiased log-uniform witness sampling.
"""
import random
from fractions import Fraction

import numpy as np
import pytest

from repro.core.compiled import (CompiledPoly, CompiledSystem, compile_pair,
                                 specialize_system)
from repro.core.constraints import (Constraint, ConstraintSystem, Rel,
                                    Verdict, _log_uniform_int, is_integer_var)
from repro.core.polynomial import Poly, V


# ---------------------------------------------------------------------------
# CompiledPoly
# ---------------------------------------------------------------------------

def test_compiled_poly_matches_exact_eval():
    p = (Fraction(3, 7) * V("x") ** 2 * V("y") - 5 * V("z")
         + V("x") * V("z") + Fraction(5, 2))
    cp = p.compile()
    assert cp is p.compile()                      # cached on the Poly
    rng = random.Random(0)
    rows = [{"x": rng.randrange(0, 50), "y": rng.randrange(0, 50),
             "z": rng.randrange(0, 50)} for _ in range(64)]
    cols = {v: np.array([r[v] for r in rows], dtype=np.int64)
            for v in ("x", "y", "z")}
    got = cp.eval_batch(cols)
    want = [float(p.eval(r)) for r in rows]
    assert np.allclose(got, want, rtol=0, atol=1e-9)
    # scaled evaluation is exact integer arithmetic under the certificate
    assert cp.max_abs_scaled({"x": 50, "y": 50, "z": 50}) < 1 << 53
    scaled = cp.eval_batch_scaled(cols)
    for s, r in zip(scaled, rows):
        assert Fraction(int(s)) == p.eval(r) * cp.scale


def test_compiled_poly_missing_variable_raises():
    cp = (V("a") * V("b")).compile()
    with pytest.raises(KeyError):
        cp.eval_batch({"a": np.array([1, 2])})


def test_compile_pair_shares_scale():
    a = Fraction(1, 6) * V("x")
    b = Fraction(1, 4) * V("y") + 1
    ca, cb = compile_pair(a, b)
    assert ca.scale == cb.scale == 12


def test_certificate_is_conservative():
    big = 1 << 60
    p = Poly.const(big) * V("x")
    cp = p.compile()
    assert cp.max_abs_scaled({"x": 2}) >= 1 << 53   # refuses to certify
    assert cp.eval_exact({"x": 2}) == Fraction(big * 2)


# ---------------------------------------------------------------------------
# CompiledSystem: classification + specialize-once decisions
# ---------------------------------------------------------------------------

def _mask_vs_reference(system, cols, maxvals, n):
    cs = specialize_system(system, {})
    assert not cs.fallback
    mask = cs.feasible_rows(cols, maxvals, n)
    for r in range(n):
        asg = {v: int(cols[v][r]) for v in cols}
        ref = system.subs(asg).check(samples=16) is not Verdict.INCONSISTENT
        assert bool(mask[r]) == ref, (asg, system)
    return mask


def test_row_atom_screen_matches_reference():
    C = ConstraintSystem([
        Constraint.ge(V("V") - 4 * V("x") * V("y")),
        Constraint.gt(V("x"), 1),
    ])
    cs = specialize_system(C, {"V": 64})
    assert cs.row_vars == {"x", "y"}
    assert not cs.measure_atoms and len(cs.row_atoms) == 2
    xs = np.array([1, 2, 2, 4, 8], dtype=np.int64)
    ys = np.array([1, 2, 8, 4, 8], dtype=np.int64)
    mask = cs.feasible_rows({"x": xs, "y": ys}, {"x": 8, "y": 8}, 5)
    #                x>1 fails ^      16 ok  64 ok  64 ok  256>64
    assert mask.tolist() == [False, True, True, True, False]


def test_measure_interval_matches_reference_randomized():
    """Vectorized interval emptiness == per-row exact check, fuzzed."""
    rng = random.Random(7)
    n = 24
    cols = {"x": np.array([rng.randrange(0, 7) for _ in range(n)],
                          dtype=np.int64),
            "y": np.array([rng.randrange(0, 7) for _ in range(n)],
                          dtype=np.int64)}
    maxvals = {"x": 6, "y": 6}
    for trial in range(60):
        atoms = [Constraint.ge(V("P_m")), Constraint.le(V("P_m"), 1)]
        for _ in range(rng.randrange(1, 4)):
            k = (rng.randrange(-3, 4) * V("x") + rng.randrange(-2, 3))
            c = (rng.randrange(-3, 4) * V("y") + rng.randrange(-6, 7))
            rel = rng.choice([Constraint.ge, Constraint.gt, Constraint.eq])
            atoms.append(rel(k * V("P_m") + c))
        _mask_vs_reference(ConstraintSystem(atoms), cols, maxvals, n)


def test_specialize_decides_fully_bound_systems():
    C = ConstraintSystem([
        Constraint.ge(V("P_occ") * V("M") - V("c")),   # P_occ >= c/M
        Constraint.le(V("P_occ"), 1),
        Constraint.ge(V("P_occ")),
    ])
    feas = specialize_system(C, {"M": 8, "c": 4})      # P_occ in [1/2, 1]
    assert feas.decided and not feas.infeasible
    infeas = specialize_system(C, {"M": 8, "c": 9})    # P_occ >= 9/8 > 1
    assert infeas.decided and infeas.infeasible
    assert C.subs({"M": 8, "c": 9}).check() is Verdict.INCONSISTENT


def test_specialize_cache_returns_same_object():
    C = ConstraintSystem([Constraint.ge(V("x") - 1)])
    assert specialize_system(C, {"x": 3}) is specialize_system(C, {"x": 3})
    assert specialize_system(C, {"x": 3}) is not specialize_system(C, {"x": 1})


def test_unclassifiable_atoms_set_fallback():
    quad = ConstraintSystem([Constraint.ge(V("P_a") * V("P_a") - 1)])
    assert specialize_system(quad, {}).fallback
    two = ConstraintSystem([Constraint.ge(V("P_a") * V("P_b") - 1)])
    assert specialize_system(two, {}).fallback


def test_uncertified_rows_fall_back_to_exact():
    big = 1 << 60
    C = ConstraintSystem([Constraint.ge(Poly.const(big) * V("x") - 5 * big)])
    cs = specialize_system(C, {})
    xs = np.array([1, 5, 7], dtype=np.int64)
    mask = cs.feasible_rows({"x": xs}, {"x": 7}, 3)
    assert mask.tolist() == [False, True, True]


def test_integer_bounds_prefilter():
    C = ConstraintSystem([Constraint.gt(V("x"), 2), Constraint.le(V("y"), 6)])
    cs = specialize_system(C, {})
    assert cs.int_bounds["x"] == (3, None)
    assert cs.int_bounds["y"] == (None, 6)
    assert cs.filter_domain("x", (1, 2, 3, 4)) == (3, 4)
    assert cs.filter_domain("y", (4, 6, 8)) == (4, 6)
    assert cs.filter_domain("z", (1, 2)) == (1, 2)


# ---------------------------------------------------------------------------
# Strict-bound tightening (integer domains) + strictness on rationals
# ---------------------------------------------------------------------------

def test_integer_var_convention():
    assert is_integer_var("bm") and is_integer_var("V")
    assert not is_integer_var("P_occ")


def test_strict_integer_gap_is_inconsistent():
    # 5 < a < 6 has no integer solution; the old epsilon hack kept it alive
    s = ConstraintSystem([Constraint.gt(V("a"), 5), Constraint.lt(V("a"), 6)])
    assert s.check() is Verdict.INCONSISTENT


def test_strict_integer_bound_is_exact_not_epsilon():
    # a > 5/2  must tighten to a >= 3 — and a = 3 must stay reachable
    s = ConstraintSystem([Constraint.gt(2 * V("a"), 5),
                          Constraint.le(V("a"), 3)])
    assert s.check() is Verdict.CONSISTENT
    w = s.witness()
    assert w is not None and w["a"] == 3


def test_strict_rational_measure_is_tracked_exactly():
    half = Fraction(1, 2)
    meet = ConstraintSystem([Constraint.gt(V("P_x"), half),
                             Constraint.lt(V("P_x"), half)])
    assert meet.check() is Verdict.INCONSISTENT
    closed = ConstraintSystem([Constraint.ge(V("P_x"), half),
                               Constraint.le(V("P_x"), half)])
    assert closed.check() is Verdict.CONSISTENT
    # a sub-epsilon open window must NOT be pruned (the old hack did)
    tiny = ConstraintSystem([Constraint.gt(V("P_x"), 0),
                             Constraint.lt(V("P_x"), Fraction(1, 10**12))])
    assert tiny.check() is not Verdict.INCONSISTENT


# ---------------------------------------------------------------------------
# Witness sampling: log-uniform without endpoint pile-up
# ---------------------------------------------------------------------------

def test_log_uniform_stays_in_box():
    rng = random.Random(0)
    lo, hi = 3, 1000
    vals = [_log_uniform_int(rng, lo, hi) for _ in range(2000)]
    assert all(lo <= v <= hi for v in vals)
    # clamping used to put ~half the mass on hi; rejection must not
    assert sum(v == hi for v in vals) / len(vals) < 0.05
    assert _log_uniform_int(rng, 5, 5) == 5
    assert _log_uniform_int(rng, 9, 2) == 9          # degenerate box


def test_witness_still_finds_small_products():
    s = ConstraintSystem([
        Constraint.ge(V("x"), 3),
        Constraint.le(V("x") * V("y"), 40),
        Constraint.ge(V("y"), 2),
    ])
    w = s.witness()
    assert w is not None and s.holds(w)
