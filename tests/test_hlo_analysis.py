"""Collective-bytes parser on hand-built HLO fragments + a real lowering."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def test_shape_bytes():
    assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H.shape_bytes("bf16[4096]") == 8192
    assert H.shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert H.shape_bytes("pred[]") == 1
    assert H.shape_bytes("token[]") == 0


SYNTH = """\
HloModule synth, num_partitions=4

%body.1 (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %ar = f32[16]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
}

%cond.1 (p: (s32[], f32[16])) -> pred[] {
  %c = s32[] constant(7)
  %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[16]) -> f32[16] {
  %ag = f32[64]{0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (s32[], f32[16]) while(%t), condition=%cond.1, body=%body.1
  %cp = f32[16]{0} collective-permute(%a), source_target_pairs={{0,1},{1,0}}
}
"""


def test_synthetic_module_weighted_counts():
    rep = H.collective_report(SYNTH, total_devices=4)
    assert rep.counts == {"all-reduce": 1, "all-gather": 1,
                          "collective-permute": 1}
    # all-gather: (n-1)/n * 64*4 = 192 ; permute: 64 bytes
    # all-reduce in the loop: 2*(3/4)*64 = 96, weighted by trip 7 -> 672
    assert rep.flat_bytes == 192 + 64 + 96
    assert rep.weighted_bytes == 192 + 64 + 96 * 7
    assert rep.weighted_counts["all-reduce"] == 7.0


def test_known_trip_count_preferred():
    mod = SYNTH.replace(
        "condition=%cond.1, body=%body.1",
        'condition=%cond.1, body=%body.1, '
        'backend_config={"known_trip_count":{"n":"13"}}')
    rep = H.collective_report(mod, total_devices=4)
    assert rep.weighted_counts["all-reduce"] == 13.0


def test_iota_replica_groups():
    mod = SYNTH.replace("replica_groups={{0,1,2,3}}, dimensions={0}",
                        "replica_groups=[2,2]<=[4]T(1,0), dimensions={0}")
    rep = H.collective_report(mod, total_devices=4)
    # all-gather group size n=2: (1/2)*256 = 128
    assert rep.by_comp["main"] >= 128


def test_real_lowering_collectives():
    """A psum under shard_map on a 1-device mesh lowers; the parser runs on
    real HLO without crashing (byte count may be 0 on 1 device)."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    f = shard_map(lambda x: jax.lax.psum(x, "x"), mesh=mesh,
                  in_specs=P("x"), out_specs=P())
    hlo = jax.jit(f).lower(jnp.ones((4, 4))).compile().as_text()
    rep = H.collective_report(hlo, total_devices=1)
    assert rep.flat_bytes >= 0
