"""Chaos-injection drills for fault-tolerant serving (ISSUE 9).

The acceptance property is **chaos parity**: for every recoverable seeded
fault schedule, ``run_until_drained`` completes with token streams
identical to the fault-free run for every non-shed request, with zero
KV-pool invariant violations (checked with block tables every tick), and
with a recorded ``DegradeEvent``/shed wherever the schedule implies one.
Unrecoverable (fatal) faults must fail loudly — and leave the engine
drainable afterwards.

Layers drilled:

* the injector itself — byte-exact schedule replay, FIFO per-site firing,
  tick gating;
* the stores — truncating/garbling a dispatch table or serve plan at
  *every byte offset* reads as a silent cache miss (the PR 1 forgiving-
  read policy), never an exception; injected I/O errors likewise;
* ``DispatchCache.demote`` — next-ranked fallback, frozen republish,
  exhaustion wrap-around, promotion-clears-demotion;
* the engine — parity sweep over seeded schedules (prefix-sharing staged
  workload, so CoW/prefill/decode/alloc sites all really run), poison-by-
  recompute, deadline/TTL cancellation, bounded-queue shedding, submit
  validation, the tick watchdog, and monitor probe failures.

Determinism: every schedule is seeded; no test depends on wall-clock time
(deadline tests inject ``FakeClock``)."""
import json

import numpy as np
import pytest

from repro.artifacts import DispatchCache
from repro.artifacts.dispatch import cand_key, set_default_cache
from repro.artifacts.store import (ArtifactStore, atomic_write_text,
                                   read_json_dict)
from repro.core import TPU_V5E
from repro.core.select import Candidate, rank_candidates
from repro.kernels.ops import FAMILIES
from repro.runtime import faults
from repro.runtime.faults import (ANY_TICK, FatalFault, FaultInjector,
                                  FaultSchedule, FaultSpec, InjectedIOFault,
                                  TickWatchdog)
from repro.runtime.kv_pool import PagedKVPool
from repro.runtime.scheduler import Request, RequestError, Scheduler

MATMUL = FAMILIES["matmul"]
DATA = {"M": 128, "N": 128, "K": 128}


@pytest.fixture(autouse=True)
def _isolate_default_cache():
    set_default_cache(DispatchCache())
    yield
    set_default_cache(None)
    faults.install(None)


# ---------------------------------------------------------------------------
# the injector: deterministic schedules, FIFO firing, tick gating
# ---------------------------------------------------------------------------

def test_random_schedules_replay_byte_exactly():
    for seed in range(20):
        a, b = FaultSchedule.random(seed), FaultSchedule.random(seed)
        assert a == b and list(a) == list(b)
    assert FaultSchedule.random(1) != FaultSchedule.random(2)


def test_specs_fire_at_their_tick_fifo_per_site():
    inj = FaultInjector([FaultSpec("pool.alloc", 3, "exhaust", arg=1),
                         FaultSpec("pool.alloc", 3, "exhaust", arg=2),
                         FaultSpec("pool.alloc", 9, "exhaust", arg=3)])
    assert inj.fire("pool.alloc") is None          # tick 0: no match
    inj.tick = 3
    assert inj.fire("pool.alloc").arg == 1         # FIFO within the tick
    assert inj.fire("pool.alloc").arg == 2
    assert inj.fire("pool.alloc") is None          # both consumed
    inj.tick = 9
    assert inj.fire("pool.alloc").arg == 3
    assert [s.arg for s in inj.fired] == [1, 2, 3]
    assert inj.pending() == []


def test_any_tick_fires_on_next_call_and_fired_log_replays():
    sched = FaultSchedule([FaultSpec("artifact.read", ANY_TICK, "io"),
                           FaultSpec("serve.decode", ANY_TICK, "error")])

    def drive():
        with faults.inject(sched) as inj:
            with pytest.raises(InjectedIOFault):
                faults.maybe_fault("artifact.read")
            with pytest.raises(faults.InjectedFault):
                faults.maybe_fault("serve.decode")
            assert faults.maybe_fault("serve.decode") is None  # consumed
            return list(inj.fired)

    assert drive() == drive()                      # identical fired logs
    assert faults.get_injector() is None           # inject() disarms


def test_inject_disarms_even_when_the_drill_raises():
    with pytest.raises(RuntimeError, match="drill"):
        with faults.inject([FaultSpec("x", ANY_TICK)]):
            raise RuntimeError("drill")
    assert faults.get_injector() is None


# ---------------------------------------------------------------------------
# stores: torn/garbled bytes at EVERY offset are a silent cache miss
# ---------------------------------------------------------------------------

def _torn_sweep(read_fn, path, site):
    """Run ``read_fn`` under a torn and a garble fault at every byte offset
    of ``path``; it must never raise, and every corrupted read must be a
    miss (``None``) — or, for a truncation that only drops trailing
    whitespace, the intact payload."""
    intact = read_fn()
    assert intact is not None
    n = len(path.read_text())
    assert n > 0
    for kind in ("torn", "garble"):
        for off in range(n):
            with faults.inject([FaultSpec(site, ANY_TICK, kind, off)]):
                got = read_fn()
            if kind == "garble":                   # NUL never parses
                assert got is None, (kind, off)
            else:
                assert got is None or got == intact, (kind, off)


def test_torn_dispatch_table_reads_as_cache_miss(tmp_path):
    store = ArtifactStore(tmp_path)
    path = store.dispatch_path("matmul", TPU_V5E.name)
    atomic_write_text(path, json.dumps(
        {"format": 2, "kind": "dispatch", "family": "matmul",
         "machine": TPU_V5E.name, "buckets": {"M128|N128": []}}))
    _torn_sweep(lambda: read_json_dict(path), path, "artifact.read")


def test_torn_serve_plan_reads_as_cache_miss(tmp_path):
    from repro.plans import serde as plan_serde
    from repro.plans.store import PlanStore
    store = PlanStore(tmp_path)
    # a structurally-valid plan written through the real serializer, read
    # through the real (forgiving) loader
    plan = plan_serde.ServePlan(
        config="torn-drill", machine=TPU_V5E.name,
        machine_bindings=dict(TPU_V5E.bindings()), max_len=64,
        page_size=8, include_train=False, entries=(), table_digests=())
    path = store.save_plan(plan)
    _torn_sweep(lambda: store.load_plan("torn-drill", TPU_V5E.name),
                path, "plan.read")


def test_injected_io_error_is_cache_miss_never_exception(tmp_path):
    store = ArtifactStore(tmp_path)
    path = store.dispatch_path("matmul", TPU_V5E.name)
    atomic_write_text(path, json.dumps({"format": 2, "kind": "dispatch"}))
    with faults.inject([FaultSpec("artifact.read", ANY_TICK, "io")]):
        assert read_json_dict(path) is None        # miss, not OSError
    assert read_json_dict(path) is not None        # spec consumed; recovers


def test_fatal_read_fault_propagates_loudly(tmp_path):
    path = tmp_path / "x.json"
    path.write_text("{}")
    with faults.inject([FaultSpec("artifact.read", ANY_TICK, "fatal")]):
        with pytest.raises(FatalFault):
            read_json_dict(path)


# ---------------------------------------------------------------------------
# DispatchCache.demote: falling down the proven ranking
# ---------------------------------------------------------------------------

def test_demote_falls_to_next_ranked_candidate():
    cache = DispatchCache()
    ranked = rank_candidates(MATMUL, TPU_V5E, DATA)
    assert cand_key(cache.best_variant(MATMUL, TPU_V5E, DATA)) == \
        cand_key(ranked[0])
    err = RuntimeError("kernel exploded")
    nxt = cache.demote(MATMUL, TPU_V5E, DATA, error=err, tick=7)
    assert cand_key(nxt) == cand_key(ranked[1])
    # sticky: subsequent resolutions keep the degraded pick
    assert cand_key(cache.best_variant(MATMUL, TPU_V5E, DATA)) == \
        cand_key(ranked[1])
    assert cache.stats.demotions == 1
    (ev,) = cache.degrade_events
    assert ev.tick == 7 and ev.family == "matmul" and not ev.exhausted
    assert ev.old == cand_key(ranked[0]) and ev.new == cand_key(ranked[1])
    assert "kernel exploded" in ev.error and "demoted" in ev.describe()


def test_demote_republishes_frozen_entry():
    cache = DispatchCache()
    ranked = rank_candidates(MATMUL, TPU_V5E, DATA)
    cache.freeze([(MATMUL, TPU_V5E, DATA)])
    before = cache.frozen_entry(MATMUL.name, TPU_V5E.name, DATA)
    assert cand_key(before.candidate) == cand_key(ranked[0])
    nxt = cache.demote(MATMUL, TPU_V5E, DATA, error=RuntimeError("x"))
    after = cache.frozen_entry(MATMUL.name, TPU_V5E.name, DATA)
    assert cand_key(after.candidate) == cand_key(nxt)
    assert cand_key(after.candidate) != cand_key(before.candidate)
    # the republished entry carries ready callables, like any frozen entry
    assert len(after.fns) == 2 and all(callable(f) for f in after.fns)


def test_demotion_exhaustion_wraps_to_top_and_resets(monkeypatch):
    """When every ranked candidate has been demoted the ladder resets to
    the top pick with ``exhausted=True`` — dispatch always answers."""
    cands = [Candidate(leaf_index=i, plan=None,
                       assignment={"bm": 2 ** (3 + i)}, score=-float(i))
             for i in range(3)]
    import repro.artifacts.dispatch as dispatch_mod
    monkeypatch.setattr(dispatch_mod, "rank_candidates",
                        lambda *a, **k: list(cands))
    cache = DispatchCache()
    assert cand_key(cache.best_variant(MATMUL, TPU_V5E, DATA)) == \
        cand_key(cands[0])
    assert cand_key(cache.demote(MATMUL, TPU_V5E, DATA,
                                 error=RuntimeError("a"))) == \
        cand_key(cands[1])
    assert cand_key(cache.demote(MATMUL, TPU_V5E, DATA,
                                 error=RuntimeError("b"))) == \
        cand_key(cands[2])
    wrapped = cache.demote(MATMUL, TPU_V5E, DATA, error=RuntimeError("c"))
    assert cand_key(wrapped) == cand_key(cands[0])
    assert cache.degrade_events[-1].exhausted
    assert not any(e.exhausted for e in cache.degrade_events[:-1])
    # the reset cleared the marks: the ladder restarts from rank 1
    assert cache.demoted_keys(MATMUL.name, TPU_V5E.name, DATA) == frozenset()
    assert cand_key(cache.demote(MATMUL, TPU_V5E, DATA,
                                 error=RuntimeError("d"))) == \
        cand_key(cands[1])
    assert cache.stats.demotions == 4


def test_promotion_clears_demotion_mark():
    """The monitor's measured re-promote (freeze_resolved publish) is the
    recovery signal: publishing a demoted candidate back into the fast
    lane drops its runtime-broken mark, so the tiers agree with the frozen
    lane."""
    cache = DispatchCache()
    ranked = rank_candidates(MATMUL, TPU_V5E, DATA)
    cache.freeze([(MATMUL, TPU_V5E, DATA)])
    cache.demote(MATMUL, TPU_V5E, DATA, error=RuntimeError("flaky"))
    assert cand_key(ranked[0]) in cache.demoted_keys(
        MATMUL.name, TPU_V5E.name, DATA)
    # measurement says the old pick recovered: promote it back
    cache.freeze_resolved([(MATMUL, TPU_V5E, DATA, ranked[0], "measured")])
    assert cache.demoted_keys(MATMUL.name, TPU_V5E.name, DATA) == frozenset()
    ent = cache.frozen_entry(MATMUL.name, TPU_V5E.name, DATA)
    assert cand_key(ent.candidate) == cand_key(ranked[0])


# ---------------------------------------------------------------------------
# scheduler-level robustness (pure host-side: no engine, no jax arrays)
# ---------------------------------------------------------------------------

def _sched(**kw):
    pool = PagedKVPool(kw.pop("num_blocks", 17), kw.pop("page_size", 8))
    return Scheduler(pool, max_batch=kw.pop("max_batch", 2),
                     max_len=kw.pop("max_len", 64), **kw)


def test_submit_validation_raises_structured_request_errors():
    s = _sched()
    for req, code in [
            (Request(1, np.array([], np.int32)), "empty_prompt"),
            (Request(2, np.arange(4, dtype=np.int32), 0), "bad_max_new"),
            (Request(3, np.arange(60, dtype=np.int32), 30), "too_long")]:
        with pytest.raises(RequestError) as ei:
            s.submit(req)
        assert ei.value.code == code and ei.value.rid == req.rid
        assert isinstance(ei.value, ValueError)    # back-compat contract
        assert ei.value.retry_after_ticks is None  # retrying cannot help
    assert s.stats.shed == 0 and not s.queue       # nothing was enqueued


def test_queue_full_sheds_with_retry_hint_never_raises():
    s = _sched(max_queue=2)
    reqs = [Request(i, np.arange(8, dtype=np.int32), 4) for i in range(5)]
    errs = [s.submit(r) for r in reqs]
    assert errs[:2] == [None, None]
    for r, e in zip(reqs[2:], errs[2:]):
        assert e is not None and e.code == "queue_full"
        assert e.retry_after_ticks >= 1
        assert r.done and r.error is e             # structured, not raised
    assert s.stats.shed == 3 and len(s.queue) == 2


def test_deadline_expires_queued_and_running(fake_clock):
    s = _sched(clock=fake_clock)
    live = Request(1, np.arange(8, dtype=np.int32), 4)
    doomed = Request(2, np.arange(8, dtype=np.int32), 4, deadline=5.0)
    s.submit(live)
    s.submit(doomed)
    plan = s.tick()                                # both admitted, in time
    assert len(plan.admitted) == 2 and not plan.cancelled
    fake_clock.advance(10.0)                       # past doomed's deadline
    plan = s.tick()
    assert [r.rid for r in plan.cancelled] == [2]
    assert doomed.done and doomed.error.code == "deadline"
    assert doomed.error.retry_after_ticks == 1
    assert s.stats.cancelled == 1
    assert not live.done                           # untouched
    # the cancelled sequence released its slot and blocks
    assert all(sq is None or sq.req.rid == 1 for sq in s.slots)
    s.pool.check_invariants(
        block_tables=[sq.blocks for sq in s.running()])


def test_deadline_expires_while_still_queued(fake_clock):
    s = _sched(max_batch=1, clock=fake_clock)
    s.submit(Request(1, np.arange(8, dtype=np.int32), 4))
    stuck = Request(2, np.arange(8, dtype=np.int32), 4, deadline=5.0)
    s.submit(stuck)                                # waits behind rid 1
    fake_clock.advance(10.0)
    plan = s.tick()
    assert stuck in plan.cancelled and stuck.error.code == "deadline"
    assert not s.queue                             # removed, not admitted


def test_poison_preempts_by_recompute():
    s = _sched()
    req = Request(1, np.arange(8, dtype=np.int32), 4)
    s.submit(req)
    s.tick()
    (seq,) = s.running()
    assert s.poison(seq)
    assert seq.dead and s.slots[seq.slot] is None
    assert s.queue[0] is req                       # requeued at the front
    assert s.stats.poisoned == 1 and s.stats.preemptions == 0
    assert not s.poison(seq)                       # already gone: moot
    s.pool.check_invariants(block_tables=[])


# ---------------------------------------------------------------------------
# the tick watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_only_outliers_after_min_samples():
    wd = TickWatchdog(factor=4.0, window=16, min_samples=4)
    for _ in range(4):
        assert not wd.observe(1.0)                 # building the baseline
    assert not wd.observe(3.9)                     # under 4x the median
    assert wd.observe(5.0)                         # over: flagged
    assert wd.stats.slow_ticks == 1 and wd.stats.worst_ratio >= 5.0
    # one hung tick cannot hide itself: it is judged against the history
    # *before* it joins the window, and the median is robust afterwards
    assert wd.observe(50.0, tick=99)
    assert wd.stats.slow_ticks == 2
    assert wd.stats.last_slow_tick == 99
    assert "slow=2" in wd.stats_line()


def test_watchdog_rejects_bad_factor():
    with pytest.raises(ValueError):
        TickWatchdog(factor=1.0)


# ---------------------------------------------------------------------------
# engine-level chaos (the acceptance sweep)
# ---------------------------------------------------------------------------

ENGINE_SITES = ("pool.alloc", "serve.cow", "serve.prefill", "serve.decode",
                "serve.tick")


@pytest.fixture(scope="module")
def smoke_model():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_model
    cfg = get_smoke_config("yi_6b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _build_engine(cfg, params, **kw):
    from repro.runtime import ServeEngine
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(cfg, params, **kw)


def _drain_checked(eng, max_ticks=300):
    """run_until_drained with the pool invariants re-proved every tick."""
    done = []
    for _ in range(max_ticks):
        done.extend(eng.step())
        eng.pool.check_invariants(
            block_tables=[s.blocks for s in eng.sched.running()])
        if not eng.sched.has_work():
            break
    while eng._inflight:
        done.extend(eng._commit(eng._inflight.popleft()))
    return done


def _chaos_prompts(cfg):
    """A leader plus followers sharing its first 22 tokens: 22 % 4 != 0
    diverges mid-block, so followers map a partial tail block and the
    scheduler must plan real CoW copies (the ``serve.cow`` site runs)."""
    rng = np.random.default_rng(1234)
    lead = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    follows = [np.concatenate([lead[:22], rng.integers(0, cfg.vocab, 6)]
                              ).astype(np.int32) for _ in range(2)]
    return [lead] + follows


def _staged_run(eng, prompts, *, max_new=5):
    """Drain the leader first (populating the prefix index), then the
    followers — mid-block divergence then forces CoW.  Pool invariants are
    proved every tick; returns {rid: tokens}."""
    outs = {}
    eng.submit(prompts[0], max_new=max_new)
    for r in _drain_checked(eng):
        outs[r.rid] = list(r.out)
    for p in prompts[1:]:
        eng.submit(p, max_new=max_new)
    for r in _drain_checked(eng):
        outs[r.rid] = list(r.out)
    return outs


@pytest.mark.slow
def test_chaos_parity_sweep(smoke_model):
    """The acceptance property: >= 12 seeded recoverable schedules across
    the engine's injection sites; every drained run is token-exact vs the
    fault-free reference, with clean pool invariants every tick."""
    cfg, params = smoke_model
    prompts = _chaos_prompts(cfg)
    ref_eng = _build_engine(cfg, params, prefix_sharing=True)
    ref = _staged_run(ref_eng, prompts)
    assert len(ref) == len(prompts)
    assert all(len(o) == 5 for o in ref.values())
    assert ref_eng.pool.stats.cow_copies >= 2      # the cow site really runs

    total_fired = 0
    for seed in range(12):
        schedule = FaultSchedule.random(seed, sites=ENGINE_SITES,
                                        max_tick=24, n=4)
        eng = _build_engine(cfg, params, prefix_sharing=True, degrade=True)
        with faults.inject(schedule) as inj:
            got = _staged_run(eng, prompts)
        assert got == ref, (seed, list(schedule), inj.fired)
        total_fired += len(inj.fired)
    assert total_fired > 0                         # the sweep injected faults


@pytest.mark.slow
def test_degrade_event_recorded_with_frozen_kernels(smoke_model):
    """A kernel-call failure under ``degrade`` with a frozen warm plan
    demotes a pick (DegradeEvent recorded) and stays token-exact."""
    cfg, params = smoke_model
    prompts = _chaos_prompts(cfg)[:2]
    ref_eng = _build_engine(cfg, params, warm_kernels=True)
    ref = {}
    for p in prompts:
        ref_eng.submit(p, max_new=5)
    for r in _drain_checked(ref_eng):
        ref[r.rid] = list(r.out)

    set_default_cache(DispatchCache())             # fresh cache per engine
    eng = _build_engine(cfg, params, warm_kernels=True, degrade=True)
    for p in prompts:
        eng.submit(p, max_new=5)
    sched = [FaultSpec("serve.prefill", 1, "error"),
             FaultSpec("serve.decode", 6, "error")]
    with faults.inject(sched) as inj:
        done = _drain_checked(eng)
    assert {r.rid: list(r.out) for r in done} == ref
    assert len(inj.fired) == 2
    assert len(eng.degrade_events) >= 1            # the schedule implies one
    assert eng._cache.stats.demotions >= 1
    assert "demotions=" in eng.robustness_line()


@pytest.mark.slow
def test_double_fault_poisons_and_recomputes(smoke_model):
    """Two faults on the same site+tick beat the one-retry budget: the
    affected sequences are poisoned (preempt-by-recompute) and every
    request still finishes with the fault-free tokens."""
    cfg, params = smoke_model
    prompts = _chaos_prompts(cfg)
    ref_eng = _build_engine(cfg, params)
    ref = {}
    for p in prompts:
        ref_eng.submit(p, max_new=5)
    for r in _drain_checked(ref_eng):
        ref[r.rid] = list(r.out)

    eng = _build_engine(cfg, params, degrade=True)
    for p in prompts:
        eng.submit(p, max_new=5)
    sched = [FaultSpec("serve.decode", 6, "error"),
             FaultSpec("serve.decode", 6, "error")]
    with faults.inject(sched) as inj:
        done = _drain_checked(eng)
    assert len(inj.fired) == 2
    assert eng.sched.stats.poisoned >= 1
    assert {r.rid: list(r.out) for r in done} == ref


@pytest.mark.slow
def test_fatal_fault_fails_loudly_engine_stays_drainable(smoke_model):
    cfg, params = smoke_model
    eng = _build_engine(cfg, params, degrade=True)
    for p in _chaos_prompts(cfg):
        eng.submit(p, max_new=4)
    with faults.inject([FaultSpec("serve.decode", ANY_TICK, "fatal")]):
        with pytest.raises(FatalFault):
            for _ in range(100):
                eng.step()
                if not eng.sched.has_work():
                    break
    # loud — but not wedged: the engine drains to completion afterwards
    done = _drain_checked(eng)
    assert len(done) == 3 and all(len(r.out) == 4 for r in done)


@pytest.mark.slow
def test_pool_exhaust_fault_forces_recovery(smoke_model):
    """Injected allocation refusals exercise the preemption/head-room
    machinery mid-flight; outputs stay token-exact."""
    cfg, params = smoke_model
    prompts = _chaos_prompts(cfg)
    ref_eng = _build_engine(cfg, params)
    ref = {}
    for p in prompts:
        ref_eng.submit(p, max_new=5)
    for r in _drain_checked(ref_eng):
        ref[r.rid] = list(r.out)

    eng = _build_engine(cfg, params)               # no degrade needed
    for p in prompts:
        eng.submit(p, max_new=5)
    sched = [FaultSpec("pool.alloc", t, "exhaust") for t in (1, 3, 5, 8)]
    with faults.inject(sched) as inj:
        done = _drain_checked(eng)
    assert len(inj.fired) >= 1
    assert eng.pool.stats.alloc_failures >= 1
    assert {r.rid: list(r.out) for r in done} == ref


@pytest.mark.slow
def test_engine_deadline_and_shed_surface_as_done(smoke_model, fake_clock):
    cfg, params = smoke_model
    prompts = _chaos_prompts(cfg) + [_chaos_prompts(cfg)[0]]
    eng = _build_engine(cfg, params, max_queue=2, deadline_ms=1000.0,
                        clock=fake_clock)
    rids = [eng.submit(p, max_new=4) for p in prompts]
    assert rids == [1, 2, 3, 4]
    done = list(eng.step())                        # surfaces the shed pair
    fake_clock.advance(10.0)                       # everything times out
    done += _drain_checked(eng)
    by_code = {}
    for r in done:
        by_code.setdefault(r.error.code if r.error else "ok", []).append(r)
    assert len(by_code.get("queue_full", [])) == 2  # max_queue=2, 4 submits
    assert len(by_code.get("deadline", [])) == 2
    assert eng.sched.stats.shed == 2 and eng.sched.stats.cancelled == 2
    assert "shed=2" in eng.robustness_line()
    eng.pool.check_invariants(block_tables=[])


@pytest.mark.slow
def test_watchdog_flags_injected_slow_tick(smoke_model):
    cfg, params = smoke_model
    eng = _build_engine(cfg, params)
    eng.submit(np.arange(2, 10), max_new=24)
    # a 10-second hang injected at tick 16, after the median settles
    with faults.inject([FaultSpec("serve.tick", 16, "slow",
                                  arg=10_000_000)]) as inj:
        _drain_checked(eng)
    assert len(inj.fired) == 1
    assert eng.watchdog.stats.slow_ticks >= 1
    assert eng.watchdog.stats.last_slow_tick == 16
    assert "watchdog" in eng.robustness_line()


@pytest.mark.slow
def test_monitor_probe_fault_is_data(smoke_model, skewed_timer):
    cfg, params = smoke_model
    eng = _build_engine(cfg, params, warm_kernels=True, monitor=True,
                        monitor_every=1, monitor_timer=skewed_timer)
    eng.submit(np.arange(2, 10), max_new=6)
    with faults.inject([FaultSpec("monitor.probe", t, "error")
                        for t in (1, 2)]) as inj:
        _drain_checked(eng)
    assert len(inj.fired) >= 1
    assert eng.monitor.stats.probe_failures >= 1   # failure is data
