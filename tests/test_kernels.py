"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.matmul import FAMILY as MATMUL, pallas_matmul
from repro.kernels.flash_attention import FAMILY as FLASH
from repro.kernels.ssd_scan import ssd_chunk


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul — paper Fig. 3/4 kernel, full parametric sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 384),
                                   (300, 200, 150), (64, 1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(M, K, N, dtype):
    a = _rand(0, (M, K), dtype)
    b = _rand(1, (K, N), dtype)
    out = ops.matmul(a, b, impl="pallas", interpret=True)
    want = ref.matmul(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("bm,bn,bk,s,cached", [
    (8, 128, 128, 1, True), (16, 128, 128, 2, True),
    (32, 128, 256, 4, False), (8, 128, 128, 8, True),
    (64, 256, 128, 1, False),
])
def test_matmul_all_block_params(bm, bn, bk, s, cached):
    """Every (block-format, grain, caching) leaf computes the same product —
    paper code-soundness (Def 2 ii) for the matmul family."""
    a = _rand(2, (256, 384))
    b = _rand(3, (384, 256))
    out = pallas_matmul(a, b, bm=bm, bn=bn, bk=bk, s=s, cached=cached,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# matadd — paper Fig. 1/2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N", [(128, 128), (257, 511), (1024, 256)])
def test_matadd(M, N):
    a = _rand(4, (M, N))
    b = _rand(5, (M, N))
    out = ops.matadd(a, b, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a + b), rtol=1e-6)


# ---------------------------------------------------------------------------
# jacobi1d — paper Fig. 7 / Table 2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,steps", [(1026, 1), (4098, 4), (32770, 2)])
def test_jacobi1d(n, steps):
    x = _rand(6, (n,))
    out = ops.jacobi1d(x, steps, impl="pallas", interpret=True)
    want = ref.jacobi1d(x, steps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# transpose — paper Fig. 8 / Table 3
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N", [(128, 128), (512, 256), (300, 700)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transpose(M, N, dtype):
    a = _rand(7, (M, N), dtype)
    out = ops.transpose(a, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a).T)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,s,d", [(2, 256, 64), (4, 512, 128), (1, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(h, s, d, causal):
    q = _rand(8, (h, s, d))
    k = _rand(9, (h, s, d))
    v = _rand(10, (h, s, d))
    out = ops.flash_attention(q, k, v, causal=causal, impl="pallas",
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_window():
    q = _rand(11, (2, 512, 64))
    k = _rand(12, (2, 512, 64))
    v = _rand(13, (2, 512, 64))
    out = ops.flash_attention(q, k, v, causal=True, window=128,
                              impl="pallas", interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# SSD scan (mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq,heads,hd,state", [
    (256, 2, 32, 16), (512, 4, 64, 32), (128, 1, 64, 64)])
def test_ssd_scan(seq, heads, hd, state):
    x = _rand(14, (seq, heads, hd))
    a = jax.nn.sigmoid(_rand(15, (seq, heads))) * 0.9 + 0.05
    b = _rand(16, (seq, heads, state))
    c = _rand(17, (seq, heads, state))
    out = ops.ssd_scan(x, a, b, c, impl="pallas", interpret=True)
    want = ref.ssd_scan(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_equals_stepwise():
    """The matmul-form chunk recurrence == naive per-token recurrence."""
    C, hd, st_ = 64, 16, 8
    x = np.asarray(_rand(18, (C, hd)))
    a = np.asarray(jax.nn.sigmoid(_rand(19, (C,))))
    b = np.asarray(_rand(20, (C, st_)))
    c = np.asarray(_rand(21, (C, st_)))
    S = np.asarray(_rand(22, (st_, hd))) * 0.1
    y, S_new = ssd_chunk(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b),
                         jnp.asarray(c), jnp.asarray(S))
    # naive recurrence
    S_ref = S.copy()
    y_ref = np.zeros((C, hd), np.float32)
    for t in range(C):
        S_ref = a[t] * S_ref + np.outer(b[t], x[t])
        y_ref[t] = c[t] @ S_ref
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_new), S_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# selection coherence: CPU tests take the same decision path as TPU builds
# ---------------------------------------------------------------------------

def test_selected_variant_is_feasible_and_deterministic():
    from repro.core import TPU_V5E, best_variant
    c1 = best_variant(MATMUL, TPU_V5E, {"M": 2048, "N": 2048, "K": 2048})
    c2 = best_variant(MATMUL, TPU_V5E, {"M": 2048, "N": 2048, "K": 2048})
    assert c1.assignment == c2.assignment
    # the chosen block parameters satisfy the leaf constraints
    C = c1.plan and None
    bm, bn, bk, s = (c1.assignment[k] for k in ("bm", "bn", "bk", "s"))
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    # VMEM constraint holds under v5e binding
    vmem = 2 * 2 * (bm * bk + bk * bn * s) + 4 * bm * bn * s * 2
    assert vmem <= TPU_V5E.vmem_bytes
