"""Direct unit tests for :mod:`repro.runtime.ft` (ISSUE 9 satellite).

The module predates the serving engine and was only covered transitively
(the chaos drills and the tick watchdog build on it); these tests pin the
pieces down in isolation: StragglerMonitor's flagging math, the elastic
mesh policy's divisor fallback, and the TrainController's checkpoint-
replay retry loop — all pure host-side, no jax."""
import numpy as np
import pytest

from repro.runtime.ft import (StragglerMonitor, TrainController,
                              elastic_mesh_shape)

# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------


def _fill(mon, host, seconds, n):
    for _ in range(n):
        mon.record(host, seconds)


def test_straggler_flagged_over_factor_times_median():
    mon = StragglerMonitor(factor=2.0, min_samples=8)
    _fill(mon, 0, 1.0, 8)
    _fill(mon, 1, 1.0, 8)
    _fill(mon, 2, 2.5, 8)                      # 2.5 > 2.0 x median(1.0)
    assert mon.stragglers() == [2]
    assert mon.medians()[2] == pytest.approx(2.5)


def test_straggler_at_factor_boundary_is_not_flagged():
    mon = StragglerMonitor(factor=2.0, min_samples=4)
    _fill(mon, 0, 1.0, 4)
    _fill(mon, 1, 1.0, 4)                      # two fast peers pin the median
    _fill(mon, 2, 2.0, 4)                      # exactly 2x: strict inequality
    assert mon.stragglers() == []


def test_straggler_needs_min_samples():
    mon = StragglerMonitor(factor=2.0, min_samples=8)
    _fill(mon, 0, 1.0, 8)
    _fill(mon, 2, 1.0, 8)
    _fill(mon, 1, 10.0, 7)                     # slow but one sample short
    assert mon.stragglers() == []
    mon.record(1, 10.0)
    assert mon.stragglers() == [1]


def test_straggler_needs_two_hosts():
    """One host has no peers to be slower than (the serving watchdog owns
    the single-host case by judging host 0 against its own history)."""
    mon = StragglerMonitor(factor=2.0, min_samples=1)
    _fill(mon, 0, 100.0, 8)
    assert mon.stragglers() == []


def test_straggler_window_forgets_old_slowness():
    mon = StragglerMonitor(factor=2.0, window=8, min_samples=4)
    _fill(mon, 0, 1.0, 8)
    _fill(mon, 2, 1.0, 8)
    _fill(mon, 1, 10.0, 8)                     # a slow phase...
    assert mon.stragglers() == [1]
    _fill(mon, 1, 1.0, 8)                      # ...fully aged out
    assert len(mon._times[1]) == 8             # window trims the buffer
    assert mon.stragglers() == []


# ---------------------------------------------------------------------------
# elastic_mesh_shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,expected", [
    (48, (3, 16)),     # divisible: model degree kept
    (64, (4, 16)),
    (24, (3, 8)),      # 24 % 16 != 0: halve to 8
    (12, (3, 4)),
    (7, (7, 1)),       # odd survivor count: model parallelism collapses
    (1, (1, 1)),       # a single device still yields a valid mesh
])
def test_elastic_mesh_shape(n, expected):
    data, model = elastic_mesh_shape(n)
    assert (data, model) == expected
    assert data * model == n                   # never strands a device


def test_elastic_mesh_prefer_model_override():
    assert elastic_mesh_shape(12, prefer_model=4) == (3, 4)
    assert elastic_mesh_shape(6, prefer_model=4) == (3, 2)


def test_elastic_mesh_rejects_zero_devices():
    with pytest.raises(ValueError):
        elastic_mesh_shape(0)


# ---------------------------------------------------------------------------
# TrainController retry replay
# ---------------------------------------------------------------------------

class _FakeCkpt:
    """In-memory CheckpointManager double recording every save/restore."""

    def __init__(self):
        self.saved = {}                        # step -> state snapshot
        self.restores = 0

    def save_async(self, step, state):
        self.saved[step] = np.array(state, copy=True)

    save = save_async

    def restore_latest(self, state):
        if not self.saved:
            return 0, None
        step = max(self.saved)
        self.restores += 1
        return step, np.array(self.saved[step], copy=True)


def _controller(ckpt, *, fault_hook=None, ckpt_every=2, max_retries=3):
    # state is a scalar ndarray; the "train step" adds the step index, so
    # any skipped or double-applied step changes the final value — replay
    # must be exact for the arithmetic to come out right
    def run_step(state, step):
        return state + step, {"loss": float(step)}

    return TrainController(run_step=run_step, ckpt=ckpt,
                           ckpt_every=ckpt_every, max_retries=max_retries,
                           fault_hook=fault_hook)


def test_controller_fault_free_run():
    ckpt = _FakeCkpt()
    state, history = _controller(ckpt).run(np.float64(0.0),
                                           start_step=0, num_steps=6)
    assert float(state) == sum(range(6))
    assert [m["step"] for m in history] == list(range(6))
    assert 6 in ckpt.saved                     # final save
    assert ckpt.restores == 0


def test_controller_replays_from_checkpoint_after_fault():
    ckpt = _FakeCkpt()
    killed = []

    def fault_hook(step):
        if step == 5 and not killed:           # kill step 5 exactly once
            killed.append(step)
            raise RuntimeError("injected host loss")

    state, history = _controller(ckpt, fault_hook=fault_hook).run(
        np.float64(0.0), start_step=0, num_steps=8)
    # replay is exact: the rerun steps (4, 5 after restoring the step-4
    # checkpoint) produce identical arithmetic, nothing double-applies
    assert float(state) == sum(range(8))
    assert killed == [5] and ckpt.restores == 1
    # history keeps both attempts' metrics; the *step* sequence rewinds
    steps = [m["step"] for m in history]
    assert steps == [0, 1, 2, 3, 4, 4, 5, 6, 7]


def test_controller_restarts_from_initial_state_without_checkpoint():
    """A fault before the first checkpoint restarts from the *initial*
    state, not just the initial step — rewinding the counter alone would
    re-apply step 1's update to a state that already contains it."""
    ckpt = _FakeCkpt()
    killed = []

    def fault_hook(step):
        if step == 2 and not killed:           # fails before any checkpoint
            killed.append(step)
            raise RuntimeError("early fault")

    state, _ = _controller(ckpt, ckpt_every=100, fault_hook=fault_hook).run(
        np.float64(0.0), start_step=1, num_steps=4)
    assert float(state) == sum(range(1, 5))    # full restart, exact replay
    assert ckpt.restores == 0                  # nothing to restore from


def test_controller_raises_after_max_retries():
    ckpt = _FakeCkpt()

    def always_fail(step):
        raise RuntimeError("persistent fault")

    with pytest.raises(RuntimeError, match="persistent fault"):
        _controller(ckpt, fault_hook=always_fail, max_retries=2).run(
            np.float64(0.0), start_step=0, num_steps=4)


def test_controller_never_swallows_keyboard_interrupt():
    ckpt = _FakeCkpt()

    def interrupt(step):
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        _controller(ckpt, fault_hook=interrupt).run(
            np.float64(0.0), start_step=0, num_steps=4)
