"""Invariants of Algorithm 1/2 (paper Definition 2 + Lemmas 1-4)."""
from fractions import Fraction

import pytest

from repro.core import (Constraint, ConstraintSystem, Verdict,
                        comprehensive_optimization, comprehensive_tree,
                        initial_quintuple, tree_report, V)
from repro.core.counters import CounterKind
from repro.kernels.flash_attention import FAMILY as FLASH
from repro.kernels.jacobi1d import FAMILY as JACOBI
from repro.kernels.matadd import FAMILY as MATADD
from repro.kernels.matmul import FAMILY as MATMUL
from repro.kernels.ssd_scan import FAMILY as SSD
from repro.kernels.transpose import FAMILY as TRANSPOSE

FAMILIES = [MATMUL, MATADD, JACOBI, TRANSPOSE, FLASH, SSD]


@pytest.fixture(scope="module", params=FAMILIES, ids=lambda f: f.name)
def family(request):
    return request.param


@pytest.fixture(scope="module")
def leaves(family):
    return comprehensive_tree(family)


def test_tree_nonempty(leaves):
    assert len(leaves) >= 2          # at least one accept/refuse fork


def test_constraint_soundness(leaves):
    """Def 2 (i): every kept system is consistent (never provably empty)."""
    for leaf in leaves:
        assert leaf.constraints.check() is not Verdict.INCONSISTENT


def test_lemma1_height_bound(family, leaves):
    """Lemma 1: #applied strategies + #constraints bounded by w(s+t).

    Each leaf's path length = number of accept edges (= evaluated counters,
    re-pushed after refuses) + refuse edges (<= w).  We check the recipe
    length |λ| <= w and constraint count <= axioms + 2*w(s+t)."""
    w = len(family.strategies())
    s_t = len(family.counters())
    for leaf in leaves:
        assert len(leaf.applied) <= w
        assert len(leaf.constraints) <= 4 + s_t + 2 * w * (s_t + 1)


def test_lemma2_strategies_explored(family, leaves):
    """Lemma 2 (pruned-tree form): some leaf applies no strategy, and the
    FIRST σ-strategy of every counter appears in some recipe.

    (Lemma 2 guarantees every strategy subset labels a path of the
    *unpruned* tree; consistency pruning legitimately removes paths whose
    extra strategy level cannot change the counter — e.g. transpose's cse_2
    after cse_1, exactly the paper's R3/R6 contradiction discard.)"""
    recipes = [set(l.applied) for l in leaves]
    assert set() in recipes                      # the all-accept path
    applied_anywhere = set().union(*recipes)
    initially_applicable = {
        s.name for s in family.strategies()
        if s(family.initial_plan()) is not None}
    for c in family.counters():
        firsts = [n for n in c.sigma if n in initially_applicable]
        if firsts:
            assert firsts[0] in applied_anywhere, \
                f"{firsts[0]} (first σ({c.name})) never explored"


def test_optimality_fixpoint(family, leaves):
    """Def 2 (iv): for each counter, some leaf is a fix-point of every
    strategy in σ(counter) — no strategy can improve it further."""
    for counter in family.counters():
        found = False
        for leaf in leaves:
            plan = leaf.plan
            fixpoint = True
            for s in family.strategies():
                if s.name not in counter.sigma:
                    continue
                transformed = s(plan)
                if transformed is None:
                    continue           # idempotence: not applicable again
                before = counter.evaluate(family, plan)
                after = counter.evaluate(family, transformed)
                if (before[0] * after[1]) != (after[0] * before[1]):
                    fixpoint = False
                    break
            if fixpoint:
                found = True
                break
        assert found, f"no optimal leaf for counter {counter.name}"


def test_coverage_on_concrete_machines(family, leaves):
    """Def 2 (iii): concrete machine+data bindings leave >= 1 live leaf."""
    from repro.core.params import TPU_V5E, PAPER_M2050
    data_samples = [
        {"M": 1024, "N": 1024, "K": 1024, "SQ": 1024, "HD": 128,
         "STATE": 64, "T": 4},
        {"M": 8192, "N": 8192, "K": 8192, "SQ": 8192, "HD": 64,
         "STATE": 128, "T": 8},
    ]
    for machine in (TPU_V5E,):
        binding = machine.bindings()
        for data in data_samples:
            live = 0
            for leaf in leaves:
                C = leaf.constraints.subs({**binding, **data})
                if C.check() is not Verdict.INCONSISTENT:
                    live += 1
            assert live >= 1, (machine.name, data)


def test_idempotence_of_strategies(family):
    """σ-strategies are idempotent on plans (paper assumption)."""
    plan = family.initial_plan()
    for s in family.strategies():
        once = s(plan)
        if once is None:
            continue
        twice = s(once)
        assert twice is None, f"{s.name} is not idempotent"


def test_report_smoke(family, leaves):
    rep = tree_report(leaves)
    assert "case 1" in rep and family.name in rep
