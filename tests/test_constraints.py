"""Constraint-system consistency checker vs brute-force enumeration.

The paper prunes branches via RealTriangularize; our stand-in must be SOUND
in the pruning direction: INCONSISTENT is only reported when the system
truly has no solution over the domain (coverage property iii depends on
this).  CONSISTENT must come with a real witness.
"""
import itertools
from fractions import Fraction

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.constraints import Constraint, ConstraintSystem, Rel, Verdict
from repro.core.polynomial import Poly, V

VARS = ["a", "b"]
BOX = range(0, 6)       # brute-force domain


@st.composite
def linear_atoms(draw):
    ca = draw(st.integers(-3, 3))
    cb = draw(st.integers(-3, 3))
    c0 = draw(st.integers(-10, 10))
    poly = ca * V("a") + cb * V("b") + c0
    rel = draw(st.sampled_from([Rel.GE, Rel.GT, Rel.EQ]))
    return Constraint(poly, rel)


@st.composite
def quadratic_atoms(draw):
    ca = draw(st.integers(-2, 2))
    cab = draw(st.integers(-2, 2))
    c0 = draw(st.integers(-20, 20))
    poly = ca * V("a") ** 2 + cab * V("a") * V("b") + c0
    rel = draw(st.sampled_from([Rel.GE, Rel.GT]))
    return Constraint(poly, rel)


def brute_force_satisfiable(system: ConstraintSystem) -> bool:
    for a, b in itertools.product(BOX, BOX):
        if system.holds({"a": Fraction(a), "b": Fraction(b)}):
            return True
    return False


def _domain_system(atoms):
    sys_ = ConstraintSystem()
    # paper H1 domain: nonneg integers; brute box adds upper bounds
    sys_.add(Constraint.ge(V("a")))
    sys_.add(Constraint.le(V("a"), BOX[-1]))
    sys_.add(Constraint.ge(V("b")))
    sys_.add(Constraint.le(V("b"), BOX[-1]))
    for a in atoms:
        sys_.add(a)
    return sys_


@settings(max_examples=200, deadline=None)
@given(st.lists(linear_atoms(), min_size=1, max_size=4))
def test_sound_pruning_linear(atoms):
    system = _domain_system(atoms)
    truth = brute_force_satisfiable(system)
    verdict = system.check()
    if verdict is Verdict.INCONSISTENT:
        assert not truth, f"pruned a satisfiable system: {system}"
    if verdict is Verdict.CONSISTENT:
        # witness claims must be real (re-verified by the checker itself,
        # but cross-check against brute force possibility)
        assert truth or _has_noninteger_solution(system)


def _has_noninteger_solution(system):
    # the checker searches rationals (perf measures live in [0,1]); a
    # consistent verdict with no integer point in the box is legal
    return True


@settings(max_examples=150, deadline=None)
@given(st.lists(quadratic_atoms(), min_size=1, max_size=3))
def test_sound_pruning_quadratic(atoms):
    system = _domain_system(atoms)
    truth = brute_force_satisfiable(system)
    if system.check() is Verdict.INCONSISTENT:
        assert not truth, f"pruned a satisfiable system: {system}"


def test_explicit_contradiction():
    s = ConstraintSystem()
    s.add(Constraint.ge(V("R"), 10))
    s.add(Constraint.lt(V("R"), 10))
    assert s.check() is Verdict.INCONSISTENT
    assert not s.is_consistent()


def test_paper_fig2_cases():
    """The two matrix-addition cases of Fig. 2 are each consistent and
    mutually exclusive in R."""
    B0xB1_le_T = Constraint.le(V("B0") * V("B1"), V("T"))
    c1 = ConstraintSystem([B0xB1_le_T, Constraint.ge(V("R"), 14)])
    c2 = ConstraintSystem([B0xB1_le_T, Constraint.ge(V("R"), 10),
                           Constraint.lt(V("R"), 14)])
    for base in (c1, c2):
        for v in ("B0", "B1", "T", "R"):
            base.add(Constraint.ge(V(v)))
    assert c1.check() is Verdict.CONSISTENT
    assert c2.check() is Verdict.CONSISTENT
    both = ConstraintSystem(c1.atoms + c2.atoms)
    assert both.check() is Verdict.INCONSISTENT


def test_witness_satisfies():
    s = ConstraintSystem([
        Constraint.ge(V("x"), 3),
        Constraint.le(V("x") * V("y"), 40),
        Constraint.ge(V("y"), 2),
    ])
    w = s.witness()
    assert w is not None and s.holds(w)


def test_substitution_then_check():
    s = ConstraintSystem([Constraint.le(V("bm") * V("bn") * 4, V("V"))])
    ok = s.subs({"V": 1 << 20, "bm": 128, "bn": 128})
    bad = s.subs({"V": 1 << 10, "bm": 128, "bn": 128})
    assert ok.check() is Verdict.CONSISTENT
    assert bad.check() is Verdict.INCONSISTENT
