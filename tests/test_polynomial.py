"""Property tests for the exact polynomial arithmetic (core substrate)."""
from fractions import Fraction

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.polynomial import Poly, V

VARS = ["x", "y", "z"]


@st.composite
def polys(draw, max_terms=4, max_exp=3):
    n = draw(st.integers(0, max_terms))
    terms = {}
    for _ in range(n):
        nvars = draw(st.integers(0, 2))
        mono = []
        used = set()
        for _ in range(nvars):
            v = draw(st.sampled_from(VARS))
            if v in used:
                continue
            used.add(v)
            mono.append((v, draw(st.integers(1, max_exp))))
        coeff = Fraction(draw(st.integers(-9, 9)), draw(st.integers(1, 5)))
        mono = tuple(sorted(mono))
        terms[mono] = terms.get(mono, Fraction(0)) + coeff
    return Poly(terms)


assignments = st.fixed_dictionaries(
    {v: st.integers(-5, 5) for v in VARS})


@settings(max_examples=150, deadline=None)
@given(polys(), polys(), assignments)
def test_add_homomorphism(p, q, asg):
    assert (p + q).eval(asg) == p.eval(asg) + q.eval(asg)


@settings(max_examples=150, deadline=None)
@given(polys(), polys(), assignments)
def test_mul_homomorphism(p, q, asg):
    assert (p * q).eval(asg) == p.eval(asg) * q.eval(asg)


@settings(max_examples=100, deadline=None)
@given(polys(), polys(), polys())
def test_ring_axioms(p, q, r):
    assert p + q == q + p
    assert p * q == q * p
    assert (p + q) + r == p + (q + r)
    assert p * (q + r) == p * q + p * r
    assert p - p == Poly.const(0)


@settings(max_examples=100, deadline=None)
@given(polys(), st.integers(0, 4), assignments)
def test_pow(p, n, asg):
    assert (p ** n).eval(asg) == p.eval(asg) ** n


@settings(max_examples=100, deadline=None)
@given(polys(), assignments)
def test_full_substitution_equals_eval(p, asg):
    sub = p.subs(asg)
    assert sub.is_constant()
    assert sub.constant_value() == p.eval(asg)


@settings(max_examples=100, deadline=None)
@given(polys(), st.integers(-5, 5), assignments)
def test_partial_substitution(p, xval, asg):
    partial = p.subs({"x": xval})
    assert "x" not in partial.variables()
    full = dict(asg)
    full["x"] = xval
    assert partial.eval(full) == p.eval(full)


@settings(max_examples=80, deadline=None)
@given(polys(), polys())
def test_substitute_poly_for_var(p, q):
    """p(x <- q) evaluated == p evaluated at q's value (composition)."""
    asg = {"x": 2, "y": 3, "z": -1}
    composed = p.subs({"x": q})
    assert composed.eval(asg) == p.eval({**asg, "x": q.eval(asg)})


def test_degree_and_vars():
    p = V("x") * V("x") * V("y") + 3
    assert p.degree() == 3
    assert p.degree("x") == 2
    assert p.degree("y") == 1
    assert p.variables() == frozenset({"x", "y"})


def test_hash_eq_semantics():
    assert hash(V("x") + 1 - 1) == hash(V("x"))
    assert V("x") * 0 == Poly.const(0)
    assert not (V("x") * 0)


def test_repr_roundtrip_smoke():
    p = 2 * V("x") ** 2 - V("y") / 3 + 1
    s = repr(p)
    assert "x^2" in s and "y" in s
