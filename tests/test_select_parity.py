"""Old-vs-new cold-path parity: the vectorized ``enumerate_candidates``
must return the identical candidate set — same assignments, same leaf
indices, same enumeration order, scores within 1e-9 — as the
``use_compiled=False`` reference path, across every registered family.

The deterministic sweep runs in the fast tier; the hypothesis property test
additionally fuzzes data shapes, machines, and the ``max_per_leaf``
truncation cap.
"""
import pytest

from repro.core import PAPER_M2050, TPU_V5E
from repro.core.select import enumerate_candidates
from repro.kernels.ops import FAMILIES

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property test skipped; deterministic one runs
    HAVE_HYPOTHESIS = False

# data-parameter names per family (matches artifacts.compile grids)
DIMS = {
    "matmul": ("M", "N", "K"),
    "matadd": ("M", "N"),
    "transpose": ("M", "N"),
    "jacobi1d": ("N",),
    "flash_attention": ("SQ", "HD"),
    "ssd_scan": ("SQ", "HD", "STATE"),
}
DIM_VALUES = (1, 7, 127, 128, 500, 1024, 4096, 100000)
MACHINES = (TPU_V5E, PAPER_M2050)


def _assert_parity(family, machine, data, max_per_leaf=512):
    fast = enumerate_candidates(family, machine, data,
                                max_per_leaf=max_per_leaf, use_compiled=True)
    ref = enumerate_candidates(family, machine, data,
                               max_per_leaf=max_per_leaf, use_compiled=False)
    assert ([(c.leaf_index, c.assignment) for c in fast]
            == [(c.leaf_index, c.assignment) for c in ref])
    for f, r in zip(fast, ref):
        assert abs(f.score - r.score) <= 1e-9, (f, r)
    return fast


def test_all_families_covered_by_dims():
    assert set(DIMS) == set(FAMILIES)


@pytest.mark.parametrize("name", sorted(DIMS))
@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
def test_parity_default_shape(name, machine):
    data = {d: v for d, v in zip(DIMS[name], (1024, 512, 512))}
    cands = _assert_parity(FAMILIES[name], machine, data)
    if machine is TPU_V5E:
        assert cands, f"no candidates for {name} on {machine.name}"


@pytest.mark.parametrize("name", sorted(DIMS))
def test_parity_truncation_cap(name):
    data = {d: v for d, v in zip(DIMS[name], (2048, 128, 256))}
    _assert_parity(FAMILIES[name], TPU_V5E, data, max_per_leaf=5)


@pytest.mark.parametrize("chunk", [1, 3, 7])
def test_parity_across_chunk_boundaries(monkeypatch, chunk):
    """Chunked screening (bounded memory + early exit) must not change the
    candidate sequence, whatever the chunk size."""
    from repro.core import select
    monkeypatch.setattr(select, "_SCREEN_CHUNK", chunk)
    data = {"M": 1024, "N": 1024, "K": 1024}
    _assert_parity(FAMILIES["matmul"], TPU_V5E, data, max_per_leaf=512)
    _assert_parity(FAMILIES["matmul"], TPU_V5E, data, max_per_leaf=4)


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("name", sorted(DIMS))
    @settings(max_examples=4, deadline=None)
    @given(data=st.data())
    def test_parity_property(name, data):
        shape = {d: data.draw(st.sampled_from(DIM_VALUES), label=d)
                 for d in DIMS[name]}
        machine = data.draw(st.sampled_from(MACHINES))
        cap = data.draw(st.sampled_from([512, 5]))
        _assert_parity(FAMILIES[name], machine, shape, max_per_leaf=cap)
