"""Sharding rules, ZeRO-1 extension, and int8 compressed all-reduce."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as dist
from repro.launch.mesh import make_host_mesh

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_basic_rules():
    cfg = get_config("llama3-8b")
    mesh = _mesh11()
    rules = dist.rules_for(cfg, mesh)
    assert rules["ff"] == "model"
    assert rules["embed"] is None                 # not an FSDP arch
    spec = dist.spec_for(("embed", "ff"), rules)
    assert spec == P(None, "model")


def test_spec_dedup_and_divisibility():
    cfg = get_config("kimi-k2-1t-a32b")           # FSDP arch
    mesh = _mesh11()
    rules = dist.rules_for(cfg, mesh)
    # expert gets 'data'; the FSDP embed entry must not reuse it
    with dist.use_mesh_rules(mesh, rules):
        spec = dist.spec_for(("expert", "embed", "ff"), rules,
                             (384, 7168, 2048))
    flat = []
    for e in spec:
        flat += list(e) if isinstance(e, tuple) else [e]
    dup = [a for a in flat if a is not None]
    assert len(dup) == len(set(dup)), spec


def test_spec_nondivisible_falls_back():
    cfg = get_config("mamba2-130m")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dict(dist.rules_for(cfg, mesh))
    rules["vocab"] = "model"
    with dist.use_mesh_rules(mesh, rules):
        # vocab 50280 % 1 == 0 on a 1-device mesh: kept
        s1 = dist.spec_for(("vocab", "embed"), rules, (50280, 768))
        assert s1 == P("model")


def test_constrain_noop_without_mesh():
    x = jax.numpy.ones((4, 4))
    y = dist.constrain(x, ("batch", None))
    assert y is x


def test_zero1_extends_largest_replicated_dim():
    mesh = _mesh11()
    sh = NamedSharding(mesh, P(None, "model"))
    leaf = jax.ShapeDtypeStruct((8, 4), jax.numpy.float32)
    from repro.launch.specs import _zero1_one
    out = _zero1_one(sh, leaf, mesh)
    assert out.spec == P("data", "model")


def test_state_shardings_cover_optimizer_tree():
    from repro.launch.specs import abstract_state, state_shardings
    from repro.optim import adamw, constant
    cfg = get_config("yi-6b")
    mesh = _mesh11()
    opt = adamw(constant(1e-3))
    params_sds, axes, opt_sds = abstract_state(cfg, opt)
    p_sh, o_sh, _ = state_shardings(cfg, mesh, params_sds, axes, opt_sds)
    n_p = len(jax.tree.leaves(p_sh, is_leaf=lambda t: isinstance(t, NamedSharding)))
    n_o = len(jax.tree.leaves(o_sh, is_leaf=lambda t: isinstance(t, NamedSharding)))
    assert n_o == 2 * n_p                      # m and v per param


COMPRESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed import compressed_psum_pod
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 64)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (17,))}
    out = compressed_psum_pod(grads, mesh, jax.random.PRNGKey(2))
    # reference: n_pods * grads (each pod holds the same replicated values)
    for k in grads:
        want = 2.0 * np.asarray(grads[k])
        got = np.asarray(out[k])
        rel = np.abs(got - want).mean() / (np.abs(want).mean() + 1e-9)
        assert rel < 0.02, (k, rel)
    print("COMPRESS_OK", rel)
""")


def test_compressed_psum_pod_numerics():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", COMPRESS_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr
