"""Versioned, deterministic serialization for comprehensive-tree artifacts.

The paper's offline/online split only works if the *offline* product — the
case discussion over symbolic machine/program/data parameters — can leave the
process that computed it.  This module gives every core object a canonical
JSON form:

  ``Poly``             — sorted ``[monomial, [num, den]]`` pairs,
  ``Constraint``       — polynomial atom + relation,
  ``ConstraintSystem`` — ordered atom list (order preserved for round-trip
                         equality; conjunction semantics are order-free),
  ``ParamDomain`` / ``KernelPlan`` / ``Leaf`` — the plan-side objects.

Canonical means byte-stable: the same tree always serializes to the same
bytes (sorted keys, sorted monomials, exact ``Fraction`` coefficients as
``[numerator, denominator]``), so artifact digests are meaningful and a
re-compile of an unchanged family is a no-op diff.

Format versioning policy (recorded in ROADMAP.md): every artifact embeds
``FORMAT_VERSION``; readers treat any mismatch as a cache miss (rebuild),
never an error.  Bump the version on *any* schema or semantic change.

Version history:
  1 — trees + dispatch tables with symbolic pre-ranked buckets (PR 1).
  2 — dispatch tables may carry optional measurement-calibration sections
      (``calibration``, ``measured_ranks``, ``compaction`` — written by
      ``scripts/tune_artifacts.py``, consumed by
      :mod:`repro.artifacts.dispatch`).  v1 artifacts are never migrated:
      per the policy above they read as a cache miss and are recompiled.
"""
from __future__ import annotations

import hashlib
import json
from fractions import Fraction
from typing import Any, Dict, List, Mapping, Sequence

from ..core.constraints import Constraint, ConstraintSystem, Rel
from ..core.plan import KernelPlan, Leaf, ParamDomain
from ..core.polynomial import Poly

FORMAT_VERSION = 2


class ArtifactFormatError(ValueError):
    """Raised when an artifact payload is structurally invalid."""


# ---------------------------------------------------------------------------
# Poly / Constraint / ConstraintSystem
# ---------------------------------------------------------------------------

def poly_to_obj(p: Poly) -> List[Any]:
    out = []
    for mono in sorted(p.terms):
        c = p.terms[mono]
        out.append([[list(ve) for ve in mono],
                    [c.numerator, c.denominator]])
    return out


def obj_to_poly(obj: Sequence[Any]) -> Poly:
    terms: Dict[Any, Fraction] = {}
    for mono_obj, (num, den) in obj:
        mono = tuple((str(v), int(e)) for v, e in mono_obj)
        terms[mono] = Fraction(int(num), int(den))
    return Poly(terms)


def constraint_to_obj(c: Constraint) -> Dict[str, Any]:
    return {"poly": poly_to_obj(c.poly), "rel": c.rel.value}


def obj_to_constraint(obj: Mapping[str, Any]) -> Constraint:
    return Constraint(obj_to_poly(obj["poly"]), Rel(obj["rel"]))


def system_to_obj(C: ConstraintSystem) -> List[Any]:
    return [constraint_to_obj(a) for a in C.atoms]


def obj_to_system(obj: Sequence[Any]) -> ConstraintSystem:
    return ConstraintSystem(obj_to_constraint(a) for a in obj)


# ---------------------------------------------------------------------------
# ParamDomain / KernelPlan / Leaf
# ---------------------------------------------------------------------------

def domain_to_obj(d: ParamDomain) -> Dict[str, Any]:
    return {"name": d.name, "candidates": list(d.candidates), "align": d.align}


def obj_to_domain(obj: Mapping[str, Any]) -> ParamDomain:
    return ParamDomain(name=str(obj["name"]),
                       candidates=tuple(int(c) for c in obj["candidates"]),
                       align=int(obj["align"]))


def plan_to_obj(p: KernelPlan) -> Dict[str, Any]:
    for k, v in p.flags.items():
        if not isinstance(v, (bool, int, float, str, type(None))):
            raise ArtifactFormatError(
                f"plan flag {k}={v!r} is not JSON-serializable")
    return {
        "family": p.family,
        "flags": dict(p.flags),
        "program_params": {n: domain_to_obj(d)
                           for n, d in p.program_params.items()},
        "notes": list(p.notes),
    }


def obj_to_plan(obj: Mapping[str, Any]) -> KernelPlan:
    return KernelPlan(
        family=str(obj["family"]),
        flags=dict(obj["flags"]),
        program_params={n: obj_to_domain(d)
                        for n, d in obj["program_params"].items()},
        notes=[str(n) for n in obj["notes"]],
    )


def leaf_to_obj(leaf: Leaf) -> Dict[str, Any]:
    return {
        "constraints": system_to_obj(leaf.constraints),
        "plan": plan_to_obj(leaf.plan),
        "applied": list(leaf.applied),
    }


def obj_to_leaf(obj: Mapping[str, Any]) -> Leaf:
    return Leaf(constraints=obj_to_system(obj["constraints"]),
                plan=obj_to_plan(obj["plan"]),
                applied=tuple(str(s) for s in obj["applied"]))


# ---------------------------------------------------------------------------
# Tree payloads + canonical bytes
# ---------------------------------------------------------------------------

def tree_to_obj(family_name: str, leaves: Sequence[Leaf],
                axioms: Sequence[Constraint] = ()) -> Dict[str, Any]:
    return {
        "format": FORMAT_VERSION,
        "kind": "tree",
        "family": family_name,
        "axioms": [constraint_to_obj(a) for a in axioms],
        "leaves": [leaf_to_obj(l) for l in leaves],
    }


def obj_to_tree(obj: Mapping[str, Any]) -> List[Leaf]:
    if obj.get("kind") != "tree":
        raise ArtifactFormatError(f"not a tree artifact: {obj.get('kind')!r}")
    return [obj_to_leaf(l) for l in obj["leaves"]]


def table_leaves(table: Mapping[str, Any]) -> Dict[int, Leaf]:
    """Parse a dispatch table's ``leaves`` section (keyed by index in the
    *full* tree — see ``compile.build_dispatch_table``)."""
    return {int(i): obj_to_leaf(obj)
            for i, obj in table.get("leaves", {}).items()}


def dumps(obj: Any) -> str:
    """Canonical (byte-stable) JSON text for any artifact payload."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def digest(obj: Any) -> str:
    return hashlib.sha256(dumps(obj).encode()).hexdigest()[:16]


def axioms_key(axioms: Sequence[Constraint] = ()) -> str:
    """Stable key for a domain-axiom set (distinguishes tree variants)."""
    if not axioms:
        return "base"
    return digest([constraint_to_obj(a) for a in axioms])
