"""Persistent case-discussion artifacts (the paper's offline/online split).

- :mod:`repro.artifacts.serde`    — versioned deterministic serialization of
  ``Poly`` / ``Constraint`` / ``ConstraintSystem`` / ``KernelPlan`` / ``Leaf``
- :mod:`repro.artifacts.store`    — filesystem layout + forgiving loads
- :mod:`repro.artifacts.compile`  — offline compiler (trees + per-machine
  dispatch tables), driven by ``scripts/compile_artifacts.py``
- :mod:`repro.artifacts.dispatch` — runtime ``DispatchCache``: frozen plan
  fast lane -> memory LRU -> disk artifact -> cold rebuild; makes
  ``best_variant`` an O(1) lookup and a frozen warm-path lookup lock-free
"""
from .serde import FORMAT_VERSION, ArtifactFormatError
from .store import ArtifactStore
from .dispatch import (DispatchCache, DispatchRecord, DispatchStats,
                       FrozenDispatchPlan, FrozenEntry, bucket_key,
                       frozen_key, get_default_cache, set_default_cache)
from .compile import (DEFAULT_DATA_GRIDS, build_dispatch_table, compile_all,
                      compile_family)

__all__ = [
    "FORMAT_VERSION", "ArtifactFormatError", "ArtifactStore",
    "DispatchCache", "DispatchRecord", "DispatchStats", "FrozenDispatchPlan",
    "FrozenEntry",
    "bucket_key", "frozen_key", "get_default_cache", "set_default_cache",
    "DEFAULT_DATA_GRIDS", "build_dispatch_table", "compile_all",
    "compile_family",
]
