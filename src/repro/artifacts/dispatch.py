"""O(1) runtime dispatch over precompiled case discussions.

``DispatchCache.best_variant`` resolves a (family, machine, data) triple
through a frozen fast lane plus three tiers:

  0. **frozen plan** — an immutable snapshot built by :meth:`DispatchCache.
     freeze` from warm-up triples (``warm_kernel_dispatch`` feeds it), or
     pinned directly from a shipped serve-plan artifact via
     :meth:`DispatchCache.freeze_resolved` (:mod:`repro.plans`).  The
     read path (:meth:`DispatchCache.warm_callable`) is a single GIL-atomic
     plain-dict lookup: no lock, no key re-sorting (canonical keys are
     ``frozenset`` item views; steady-state keys are learned call-site item
     tuples), and each entry carries the **pre-instantiated kernel
     callables** so a warm op call never rebuilds a ``pallas_call``.
     Misses fall through to the locked tiers;
  1. **memory LRU** — exact-key memo of resolved :class:`Candidate`s; a
     recurring triple (the serving steady state) costs one dict lookup;
  2. **disk artifact** — a per-machine dispatch table compiled offline
     (:mod:`repro.artifacts.compile`): leaves pre-specialized against the
     machine bindings and candidates pre-ranked per data-shape *bucket*
     (dims rounded up to powers of two).  On a bucket hit the ranked list is
     re-validated against the *exact* data — a constant number of constraint
     substitutions, no enumeration — so an off-grid shape still gets a sound
     answer from the precompiled ranking;
  3. **cold rebuild** — full ``rank_candidates`` over the tree (itself
     loaded from the tree artifact when present, rebuilt in-process when
     not).

Within tier 2, a FORMAT_VERSION-2 table may carry a ``measured_ranks``
section written by ``scripts/tune_artifacts.py`` (see :mod:`repro.tuning`):
per bucket, the candidate order observed on real hardware.  When present
and well-formed it *reorders* the shortlist walk — measured rank beats the
symbolic score — but it can never add candidates; feasibility still comes
from the leaf constraints alone.

Invariants this module maintains (tests enforce them):

- **cache-miss-never-error** — a missing, unreadable, version-mismatched,
  or field-mangled table (including a malformed ``measured_ranks`` or
  ``calibration`` section) degrades to the next tier; no artifact content
  can raise out of ``best_variant``;
- **soundness** — tier 2 never invents feasibility: every candidate it
  returns passes the same leaf-constraint check the cold path applies; if
  the whole precompiled shortlist fails for the exact data, we fall through
  to tier 3;
- **parity without tuning** — a table with no ``measured_ranks`` section
  resolves exactly as the symbolic cold path would (asserted by the
  artifact/tuning test suites);
- **frozen parity** — ``freeze`` snapshots resolutions produced by the very
  tiers above, so with and without a frozen plan every triple resolves to
  the same candidate (asserted by the fast-lane tests).
"""
from __future__ import annotations

import contextlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import (Any, Callable, Dict, FrozenSet, Iterable, Iterator, List,
                    Mapping, Optional, Sequence, Tuple)

from ..core.constraints import Verdict
from ..core.params import MachineDescription
from ..core.plan import FamilySpec, Leaf
from ..core.select import Candidate, rank_candidates
from ..obs import recorder as obs
from ..obs.events import DispatchDecision, describe_transition
from . import serde
from .store import ArtifactStore

DispatchKey = Tuple[str, str, Tuple[Tuple[str, int], ...]]
FrozenKey = Tuple[str, str, FrozenSet[Tuple[str, int]]]

#: Identity of a candidate for demotion/comparison purposes: the leaf it
#: came from + its full program-parameter assignment (scores are *model*
#: opinions and excluded).  The runtime monitor re-exports these.
CandKey = Tuple[int, Tuple[Tuple[str, int], ...]]


def cand_key(c: Candidate) -> CandKey:
    return (int(c.leaf_index),
            tuple(sorted((k, int(v)) for k, v in c.assignment.items())))


@dataclass(frozen=True)
class DegradeEvent:
    """One observable fall down the candidate ranking (the failure-path
    mirror of the monitor's ``SwapEvent``): which pick raised, what
    replaced it, and why.  ``exhausted`` flags a full wrap-around — every
    ranked candidate had been demoted, so the ladder reset to the top pick
    rather than leave the triple unresolvable (cache-miss-never-error
    extends to demotion: dispatch always answers)."""

    tick: int
    family: str
    machine: str
    data: Tuple[Tuple[str, int], ...]        # sorted items
    old: CandKey
    new: CandKey
    error: str                               # repr of the triggering failure
    source: str                              # tier that decided the fallback
    exhausted: bool = False

    def describe(self) -> str:
        # rendered through the shared obs convention so the degrade and
        # swap logs cannot drift (a test pins this format)
        tail = " [ladder exhausted; reset]" if self.exhausted else ""
        return describe_transition(
            tick=self.tick, verb="demoted", family=self.family,
            data=self.data, old=str(self.old[1]), new=str(self.new[1]),
            note=self.source, cause=self.error, tail=tail)


def frozen_key(family_name: str, machine_name: str,
               data: Mapping[str, int]) -> FrozenKey:
    """Fast-lane key: hashing a ``frozenset`` skips the LRU key's sort."""
    return (family_name, machine_name,
            frozenset((k, int(v)) for k, v in data.items()))


@dataclass(frozen=True)
class FrozenEntry:
    """One warm-up triple's snapshot: the resolved candidate, the tier that
    decided it, and the memoized kernel callables for both ``interpret``
    modes (identity-stable — built once through the family's instantiation
    cache, so jit tracing keys never churn)."""

    candidate: Candidate
    source: str                            # "measured" | "symbolic" | "cold"
    fns: Tuple[Callable, Callable]         # (interpret=False, interpret=True)


def _pin_entry(family: FamilySpec, cand: Candidate,
               source: str) -> FrozenEntry:
    """Build one frozen entry: the memoized (identity-stable) kernel
    callables for both interpret modes.  Single-sourced so entries pinned
    online (``freeze``) and from a shipped plan (``freeze_resolved``) can
    never be constructed differently."""
    fns = tuple(
        family.instantiate(cand.plan, cand.assignment, interpret=interp,
                           leaf_index=cand.leaf_index)
        for interp in (False, True))
    return FrozenEntry(candidate=cand, source=source, fns=fns)


class FrozenDispatchPlan:
    """Immutable (family, machine, shape) -> :class:`FrozenEntry` resolver.

    Once constructed the entry dict is never mutated, so concurrent readers
    need no lock: ``DispatchCache.freeze`` publishes a *new* plan object and
    swaps the reference, which is atomic under the GIL.

    The steady-state lookup (:meth:`DispatchCache.warm_callable`) keys an
    *fns alias table* on ``(family object, machine name, items tuple,
    interpret)`` and maps straight to the ready kernel callable: the family
    object hashes by identity, the machine name's string hash is cached,
    and the items tuple is whatever ordering the call site builds — no
    sort, no per-item ``int()`` coercion, no intermediate entry object.
    First contact from a call site goes through the canonical
    order-insensitive :func:`frozen_key` (:meth:`learn_fn`) and memoizes
    the cheap key.  Alias inserts are plain-dict stores (GIL-atomic,
    monotonic, bounded by frozen-triples x call sites x 2); the entry map
    itself stays frozen."""

    __slots__ = ("_entries", "_fns", "triples")

    def __init__(self, entries: Mapping[FrozenKey, FrozenEntry],
                 triples: Tuple[Tuple[FamilySpec, MachineDescription,
                                      Mapping[str, int]], ...] = ()):
        self._entries: Dict[FrozenKey, FrozenEntry] = dict(entries)
        self._fns: Dict[Tuple[Any, str, Tuple[Tuple[str, int], ...], bool],
                        Callable] = {}
        #: the (family, machine, data) warm-up set this plan snapshots —
        #: kept so a late store attach can re-freeze the same triples
        #: against the new tables instead of pinning stale answers
        self.triples = tuple(triples)

    def get(self, family_name: str, machine_name: str,
            data: Mapping[str, int]) -> Optional[FrozenEntry]:
        return self._entries.get(frozen_key(family_name, machine_name, data))

    def learn_fn(self, family: FamilySpec, machine_name: str,
                 items: Tuple[Tuple[str, int], ...],
                 interpret: bool) -> Optional[Callable]:
        """Slow half of the fns-alias lookup: canonical resolution + alias
        memoization for this call site's item ordering."""
        ent = self._entries.get(
            frozen_key(family.name, machine_name, dict(items)))
        if ent is None:
            return None
        fn = ent.fns[1 if interpret else 0]
        self._fns[(family, machine_name, items, interpret)] = fn
        return fn

    def entries(self) -> Dict[FrozenKey, FrozenEntry]:
        """Copy of the entry map (freeze merges through this)."""
        return dict(self._entries)

    def __len__(self) -> int:
        return len(self._entries)


class DispatchRecord:
    """Ordered, deduplicated log of dispatch requests seen while a
    :meth:`DispatchCache.record` context is active.

    Each request is normalized to ``(family_name, machine_name, sorted data
    items)`` so the same triple reached through ``best_variant`` and through
    an op wrapper's ``warm_callable`` items tuple records identically.
    ``counts`` keeps the raw request multiplicity per triple.  Recording is
    a tracing/observability mode (``repro.plans.trace`` drives model steps
    through it): appends are plain GIL-atomic dict/list stores, adequate for
    the single-threaded trace drivers, not a concurrency surface."""

    __slots__ = ("requests", "counts")

    def __init__(self) -> None:
        self.requests: List[Tuple[str, str, Tuple[Tuple[str, int], ...]]] = []
        self.counts: Dict[Tuple[str, str, Tuple[Tuple[str, int], ...]],
                          int] = {}

    def add(self, family_name: str, machine_name: str,
            data: Mapping[str, int]) -> None:
        key = (family_name, machine_name,
               tuple(sorted((k, int(v)) for k, v in data.items())))
        n = self.counts.get(key)
        if n is None:
            self.requests.append(key)
            self.counts[key] = 1
        else:
            self.counts[key] = n + 1

    def triples(self) -> List[Tuple[str, str, Dict[str, int]]]:
        """The recorded warm set, first-request order, one row per triple."""
        return [(f, m, dict(items)) for f, m, items in self.requests]

    def __len__(self) -> int:
        return len(self.requests)


def bucket_key(data: Mapping[str, int]) -> str:
    """Canonical data-shape bucket: each dim rounded up to a power of two."""
    parts = []
    for k in sorted(data):
        v = max(1, int(data[k]))
        parts.append(f"{k}{1 << (v - 1).bit_length()}")
    return "|".join(parts)


@dataclass
class DispatchStats:
    """Per-cache resolution counters.

    ``memory_hits``/``disk_hits``/``cold_builds`` are incremented under the
    cache lock — every locked-tier resolution bumps exactly one of them, so
    their sum equals the number of non-frozen ``best_variant`` calls even
    under concurrency (the regression tests assert this).  ``frozen_hits``
    is bumped on the lock-free ``best_variant``/``frozen_entry`` fast paths
    and is therefore *monotonic but approximate* under extreme contention —
    observability must not cost the hot path a lock.  ``warm_callable``,
    the nanosecond lane, is deliberately uncounted (see its docstring)."""

    memory_hits: int = 0
    disk_hits: int = 0
    cold_builds: int = 0
    measured_hits: int = 0        # disk hits served in measured (tuned) order
    frozen_hits: int = 0          # fast-lane hits (lock-free, approximate)
    demotions: int = 0            # candidates demoted after a runtime failure

    def reset(self) -> None:
        self.memory_hits = self.disk_hits = self.cold_builds = 0
        self.measured_hits = self.frozen_hits = self.demotions = 0

    def as_dict(self) -> Dict[str, int]:
        return {"memory_hits": self.memory_hits, "disk_hits": self.disk_hits,
                "cold_builds": self.cold_builds,
                "measured_hits": self.measured_hits,
                "frozen_hits": self.frozen_hits,
                "demotions": self.demotions}


class DispatchCache:
    """Memory LRU -> disk artifact -> cold rebuild, per paper's load-time split.

    Thread notes: the LRU, memoized tables/trees, and stats are lock-
    protected; concurrent misses on the same uncached triple may duplicate
    the (idempotent) tier-2/3 work, with one winner filling the LRU."""

    def __init__(self, store: Optional[ArtifactStore] = None,
                 maxsize: int = 4096,
                 store_resolver: Optional[
                     Callable[[], Optional[ArtifactStore]]] = None):
        self.store = store
        # re-probed on tier-2/3 entry while no store is attached (an artifact
        # dir that appears after first dispatch must not be ignored forever);
        # deliberately NOT consulted on the frozen/LRU hit paths, which stay
        # syscall-free
        self._store_resolver = store_resolver
        self.maxsize = maxsize
        self.stats = DispatchStats()
        # key -> (candidate, source) where source records which ranking
        # decided the original resolution: "measured" | "symbolic" | "cold"
        self._lru: "OrderedDict[DispatchKey, Tuple[Candidate, str]]" = \
            OrderedDict()
        # key -> the winning candidate's walk rank in the ranking that
        # decided it (provenance for the obs DispatchDecision records;
        # evicted/invalidated in lockstep with the LRU)
        self._ranks: Dict[DispatchKey, int] = {}
        # (family, machine) -> (raw payload, leaves parsed once) or None
        self._tables: Dict[Tuple[str, str],
                           Optional[Tuple[Dict[str, Any],
                                          Dict[int, Leaf]]]] = {}
        self._trees: Dict[str, Optional[List[Leaf]]] = {}
        self._lock = threading.Lock()
        # recording mode (see record()): None except while a trace is active
        self._recorder: Optional[DispatchRecord] = None
        # graceful degradation (see demote()): per-triple candidate keys the
        # runtime proved broken; the tiers skip them until a promotion
        # (frozen publish of a marked candidate) or exhaustion-reset clears
        # the mark
        self._demoted: Dict[DispatchKey, set] = {}
        self.degrade_events: List[DegradeEvent] = []
        # fast lane: swapped atomically by freeze(), read without the lock
        self.frozen_plan: Optional[FrozenDispatchPlan] = None
        # bumped by unfreeze()/clear(); attach_store's re-freeze aborts if
        # it changed, so an explicit drop is never silently resurrected
        self._unfreeze_gen = 0

    # -- public API ----------------------------------------------------------
    def best_variant(self, family: FamilySpec, machine: MachineDescription,
                     data: Mapping[str, int]) -> Candidate:
        return self.best_variant_with_source(family, machine, data)[0]

    def best_variant_with_source(self, family: FamilySpec,
                                 machine: MachineDescription,
                                 data: Mapping[str, int]
                                 ) -> Tuple[Candidate, str]:
        """Resolve, also reporting which ranking decided the candidate:
        ``"measured"`` (tuned table order), ``"symbolic"`` (precompiled
        offline ranking), or ``"cold"`` (tier-3 rebuild).  A memory hit
        returns the source recorded when the triple was first resolved, so
        attribution is race-free under concurrent callers."""
        rec = self._recorder
        if rec is not None:
            rec.add(family.name, machine.name, data)
        frozen = self.frozen_plan                 # snapshot: freeze() swaps whole
        if frozen is not None:
            ent = frozen.get(family.name, machine.name, data)
            if ent is not None:
                self.stats.frozen_hits += 1   # lock-free => approximate
                if obs._recorder is not None:
                    key = (family.name, machine.name,
                           tuple(sorted((k, int(v))
                                        for k, v in data.items())))
                    self._emit_decision(key, ent.candidate, ent.source,
                                        0, 0, surface="frozen")
                return ent.candidate, ent.source
        return self._resolve_tiers(family, machine, data)

    def _resolve_tiers(self, family: FamilySpec,
                       machine: MachineDescription,
                       data: Mapping[str, int]) -> Tuple[Candidate, str]:
        """Tiers 1-3 only (no frozen-plan consult): the shared resolution
        body, called directly by ``freeze`` so a *re*-freeze re-reads the
        (possibly newly attached or re-tuned) tables instead of replaying
        its own previous snapshot."""
        key: DispatchKey = (family.name, machine.name,
                            tuple(sorted((k, int(v)) for k, v in data.items())))
        with self._lock:
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                self.stats.memory_hits += 1
                rank = self._ranks.get(key, -1)
                demoted = len(self._demoted.get(key, ()))
                self._emit_decision(key, hit[0], hit[1], rank, demoted)
                return hit
            excluded = frozenset(self._demoted.get(key, ()))

        hit2 = self._from_disk(family, machine, data, exclude=excluded)
        if hit2 is None:
            ranked = rank_candidates(family, machine, data,
                                     leaves=self._tree(family))
            rank = next((i for i, c in enumerate(ranked)
                         if cand_key(c) not in excluded),
                        0)           # ladder exhausted: wrap to the top pick
            cold = ranked[rank]

        with self._lock:
            if hit2 is not None:
                cand, measured, rank = hit2
                source = "measured" if measured else "symbolic"
                self.stats.disk_hits += 1
                if measured:
                    self.stats.measured_hits += 1
            else:
                self.stats.cold_builds += 1
                cand, source = cold, "cold"
            self._lru[key] = (cand, source)
            self._lru.move_to_end(key)
            self._ranks[key] = rank
            while len(self._lru) > self.maxsize:
                old_key, _ = self._lru.popitem(last=False)
                self._ranks.pop(old_key, None)
        self._emit_decision(key, cand, source, rank, len(excluded))
        return cand, source

    def _emit_decision(self, key: DispatchKey, cand: Candidate, source: str,
                       rank: int, demoted: int,
                       surface: str = "resolve") -> None:
        """Trace one resolution as a :class:`DispatchDecision` — the
        decision-provenance record (tree leaf + assignment + bucket +
        deciding ranking + walk rank + demotion marks in effect).  One
        module-global load when tracing is off."""
        rec = obs._recorder
        if rec is None:
            return
        rec.emit(DispatchDecision(
            tick=rec.tick, family=key[0], machine=key[1], data=key[2],
            bucket=bucket_key(dict(key[2])), leaf=int(cand.leaf_index),
            assignment=tuple(sorted((k, int(v))
                             for k, v in cand.assignment.items())),
            source=source, surface=surface, rank=int(rank),
            demoted=int(demoted)))

    # -- graceful degradation ------------------------------------------------
    def demote(self, family: FamilySpec, machine: MachineDescription,
               data: Mapping[str, int], *,
               candidate: Optional[Candidate] = None,
               error: Optional[BaseException] = None,
               tick: int = -1) -> Candidate:
        """A runtime failure disproved the triple's current pick: fall down
        the already-proven ranking to the next feasible variant.

        The failing ``candidate`` (defaulting to the triple's current
        resolution) is marked broken for this triple; the replacement is
        re-resolved through the normal tiers with marked candidates
        skipped — so the fallback order *is* the case discussion's ranking
        (measured beats symbolic beats cold), not a separate policy.  If
        the triple is frozen, the replacement is republished through the
        atomic ``freeze_resolved`` merge so the lock-free lane degrades
        too.  When every ranked candidate has been demoted the ladder
        resets: marks are cleared, the top pick returns, and the event is
        flagged ``exhausted`` — dispatch always answers (the engine's
        retry budget, not the cache, decides when to give up on a
        request).  Marks are cleared early when a candidate is re-promoted
        into the frozen lane (the monitor's measured recovery path).

        Returns the replacement candidate; records a :class:`DegradeEvent`
        in :attr:`degrade_events` and bumps ``stats.demotions``."""
        key: DispatchKey = (family.name, machine.name,
                            tuple(sorted((k, int(v))
                                         for k, v in data.items())))
        if candidate is None:
            ent = self.frozen_entry(family.name, machine.name, data)
            if ent is not None:
                candidate = ent.candidate
            else:
                with self._lock:
                    hit = self._lru.get(key)
                candidate = hit[0] if hit is not None else None
        if candidate is None:                 # never resolved: resolve first
            candidate = self._resolve_tiers(family, machine, data)[0]
        old_key = cand_key(candidate)
        with self._lock:
            self._demoted.setdefault(key, set()).add(old_key)
            self._lru.pop(key, None)          # replacement re-resolves fresh
            self._ranks.pop(key, None)
            self.stats.demotions += 1
        new_cand, source = self._resolve_tiers(family, machine, data)
        exhausted = cand_key(new_cand) in self._demoted.get(key, ())
        if exhausted:                         # full wrap-around: reset ladder
            with self._lock:
                self._demoted.pop(key, None)
        frozen = self.frozen_plan
        if frozen is not None and \
                frozen.get(family.name, machine.name, data) is not None:
            self.freeze_resolved([(family, machine, data, new_cand, source)])
        event = DegradeEvent(
            tick=int(tick), family=family.name, machine=machine.name,
            data=key[2], old=old_key, new=cand_key(new_cand),
            error=repr(error) if error is not None else "",
            source=source, exhausted=exhausted)
        self.degrade_events.append(event)
        if obs._recorder is not None:         # join the provenance stream
            obs._recorder.emit(event)
        return new_cand

    def demoted_keys(self, family_name: str, machine_name: str,
                     data: Mapping[str, int]) -> FrozenSet[CandKey]:
        """The triple's current runtime-broken marks (observability)."""
        key: DispatchKey = (family_name, machine_name,
                            tuple(sorted((k, int(v))
                                         for k, v in data.items())))
        with self._lock:
            return frozenset(self._demoted.get(key, ()))

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self._ranks.clear()
            self._tables.clear()
            self._trees.clear()
            self.stats.reset()
            self.frozen_plan = None
            self._demoted.clear()
            self.degrade_events.clear()
            self._unfreeze_gen += 1

    def attach_store(self, store: Optional[ArtifactStore]) -> None:
        """Swap the disk tier, dropping table/tree memos pinned against the
        old store (``get_default_cache`` uses this when an artifact dir
        appears after first dispatch).  The LRU is dropped too: triples
        resolved cold before the store appeared must re-resolve against the
        (possibly tuned) tables, not stay pinned to their cold answer.
        A frozen plan is *re-frozen* over its own warm-up triples for the
        same reason — the serving hot path must not keep replaying
        pre-artifact cold picks (tier parity: the re-freeze resolves
        through the new tables)."""
        with self._lock:
            self.store = store
            self._tables.clear()
            self._trees.clear()
            self._lru.clear()
            self._ranks.clear()
            plan, self.frozen_plan = self.frozen_plan, None
            gen = self._unfreeze_gen
        if plan is not None and plan.triples:
            # re-pin against the new store — unless someone unfreezes while
            # we resolve, in which case their drop wins (no resurrection)
            self.freeze(plan.triples, _expect_unfreeze_gen=gen)

    def __len__(self) -> int:
        return len(self._lru)

    # -- tier 0: frozen dispatch plans ---------------------------------------
    def freeze(self, triples: Iterable[Tuple[FamilySpec, MachineDescription,
                                             Mapping[str, int]]],
               *, _expect_unfreeze_gen: Optional[int] = None
               ) -> Optional[FrozenDispatchPlan]:
        """Snapshot resolutions for ``triples`` into the lock-free fast lane.

        Each triple is resolved through the normal tiers (warming the LRU),
        then pinned — candidate, deciding source, and the memoized kernel
        callables for both ``interpret`` modes — into a fresh immutable
        :class:`FrozenDispatchPlan` merged over any previous plan (freezing
        is monotonic until :meth:`unfreeze`/:meth:`clear`).  Publishing is a
        single reference swap, so resolves racing a concurrent ``freeze``
        see either the old or the new plan, never a torn one.

        Parity is structural: a frozen entry replays exactly what the tiers
        resolved at freeze time, and the tiers themselves are deterministic
        for fixed artifacts — so freezing can change the cost of a lookup,
        never its answer.  Resolution deliberately bypasses the existing
        frozen plan (:meth:`_resolve_tiers`): re-freezing a triple re-reads
        the current tables, so warm-up after compiling/tuning artifacts
        refreshes stale cold snapshots instead of re-pinning them."""
        resolved: Dict[FrozenKey, FrozenEntry] = {}
        new_triples: Dict[FrozenKey, Tuple[Any, Any, Mapping[str, int]]] = {}
        for family, machine, data in triples:
            cand, source = self._resolve_tiers(family, machine, data)
            key = frozen_key(family.name, machine.name, data)
            resolved[key] = _pin_entry(family, cand, source)
            new_triples[key] = (family, machine, data)
        return self._publish_frozen(resolved, new_triples,
                                    _expect_unfreeze_gen)

    def freeze_resolved(self, entries: Iterable[
            Tuple[FamilySpec, MachineDescription, Mapping[str, int],
                  Candidate, str]],
            *, _expect_unfreeze_gen: Optional[int] = None
            ) -> Optional[FrozenDispatchPlan]:
        """Pin *externally resolved* triples into the fast lane.

        Each entry carries its own :class:`Candidate` and deciding source, so
        no tier is consulted and no tree is enumerated — this is how a
        shipped serve-plan artifact (:mod:`repro.plans`) starts a process
        with ``stats.cold_builds == 0``.  The kernel callables still come
        from the family's memoized ``instantiate`` (identity-stable), and
        publication merges over any existing plan exactly like
        :meth:`freeze`.  The triples are remembered, so a later
        ``attach_store`` re-freeze re-resolves them through the (new) tiers
        — plan-fed picks are re-pinned against fresh tables, not kept
        authoritative forever."""
        resolved: Dict[FrozenKey, FrozenEntry] = {}
        new_triples: Dict[FrozenKey, Tuple[Any, Any, Mapping[str, int]]] = {}
        for family, machine, data, cand, source in entries:
            key = frozen_key(family.name, machine.name, data)
            resolved[key] = _pin_entry(family, cand, source)
            new_triples[key] = (family, machine, data)
        return self._publish_frozen(resolved, new_triples,
                                    _expect_unfreeze_gen)

    def _publish_frozen(self, resolved: Dict[FrozenKey, FrozenEntry],
                        new_triples: Dict[FrozenKey, Tuple[Any, Any,
                                                           Mapping[str, int]]],
                        expect_unfreeze_gen: Optional[int]
                        ) -> Optional[FrozenDispatchPlan]:
        """Shared merge-and-swap tail of freeze/freeze_resolved."""
        with self._lock:
            if (expect_unfreeze_gen is not None
                    and self._unfreeze_gen != expect_unfreeze_gen):
                return self.frozen_plan       # a concurrent unfreeze won
            old = self.frozen_plan
            merged = old.entries() if old is not None else {}
            merged.update(resolved)
            all_triples = {frozen_key(f.name, m.name, d): (f, m, d)
                           for f, m, d in (old.triples if old is not None
                                           else ())}
            all_triples.update(new_triples)
            plan = FrozenDispatchPlan(merged, tuple(all_triples.values()))
            self.frozen_plan = plan
            # promotion clears demotion: publishing a candidate into the
            # fast lane (the monitor's measured re-promote path) is the
            # evidence it recovered — the locked tiers must agree with the
            # frozen lane, so its runtime-broken mark is dropped
            if self._demoted:
                for fkey, ent in resolved.items():
                    fam, mach, d = new_triples[fkey]
                    dkey: DispatchKey = (
                        fam.name, mach.name,
                        tuple(sorted((k, int(v)) for k, v in d.items())))
                    marks = self._demoted.get(dkey)
                    if marks is not None:
                        marks.discard(cand_key(ent.candidate))
                        if not marks:
                            del self._demoted[dkey]
        return plan

    # -- recording mode (warm-set tracing) -----------------------------------
    @contextlib.contextmanager
    def record(self) -> Iterator[DispatchRecord]:
        """Record every dispatch request while the context is active.

        The counted entry points are ``best_variant``/
        ``best_variant_with_source`` (and everything routed through them,
        e.g. ``core.select.best_variant``) and the ops-layer
        ``warm_callable`` — i.e. exactly the requests a model step issues.
        :mod:`repro.plans.trace` drives abstract prefill/decode/train steps
        under this context to derive a config's true warm set.  Contexts do
        not nest usefully (the innermost recorder wins and is restored on
        exit); recording costs the hot path one attribute test when off."""
        rec = DispatchRecord()
        prev, self._recorder = self._recorder, rec
        try:
            yield rec
        finally:
            self._recorder = prev

    @property
    def unfreeze_generation(self) -> int:
        """Current unfreeze generation, for publish-if-unchanged races.

        Capture before resolving a replacement plan off-lock, then pass to
        ``freeze``/``freeze_resolved`` as ``_expect_unfreeze_gen``: if any
        ``unfreeze``/``clear`` landed in between, the publish aborts and the
        explicit drop wins (the ``attach_store`` re-freeze discipline; the
        runtime monitor's hot-swap uses the same guard)."""
        with self._lock:
            return self._unfreeze_gen

    def unfreeze(self) -> None:
        """Drop the frozen plan; the locked tiers keep serving.

        Taken under the lock so a ``freeze`` racing this call cannot
        resurrect dropped entries: freeze's merge-and-publish also holds
        the lock, so it sees either the plan (drop wins afterwards) or
        ``None`` (merge starts empty) — never a torn in-between.  The
        generation bump additionally aborts an in-flight ``attach_store``
        re-freeze, which captured its plan *before* this drop."""
        with self._lock:
            self.frozen_plan = None
            self._unfreeze_gen += 1

    def frozen_entry(self, family_name: str, machine_name: str,
                     data: Mapping[str, int]) -> Optional[FrozenEntry]:
        """Lock-free fast-lane lookup by data mapping: the entry with the
        pre-built callables, or ``None`` when the triple was never frozen
        (callers fall back to the locked tiers)."""
        frozen = self.frozen_plan
        if frozen is None:
            return None
        ent = frozen.get(family_name, machine_name, data)
        if ent is not None:
            self.stats.frozen_hits += 1       # lock-free => approximate
        return ent

    def warm_callable(self, family: FamilySpec,
                      machine: MachineDescription,
                      items: Tuple[Tuple[str, int], ...],
                      interpret: bool = False) -> Callable:
        """The warm op path (``kernels.ops`` wrappers call this per op):
        resolve (family, machine, items) straight to a ready kernel callable.

        Frozen hit: one alias-dict get, no lock, no key sort, no entry
        indirection, no rebuild — this is the hottest function in the
        serving steady state (per-call ns here multiply by tokens x ops x
        requests), which is also why it deliberately does NOT bump
        ``stats.frozen_hits``: the counted observability surfaces are
        ``best_variant*``/``frozen_entry``, and benchmarks time this lane
        directly.  Miss: locked LRU resolve + the family's *memoized*
        ``instantiate`` — still zero ``pallas_call`` rebuilds, identical
        candidate (frozen parity), just a lock and a sorted key dearer.

        ``items`` is the data mapping as an items tuple (any order); the
        first call from a given site teaches the plan its ordering.

        Observability contract: with obs tracing off (or on at the
        default sampling) this lane stays exactly as described above —
        each recorder check is one module-global load + ``is None`` test,
        no counters.  ``FlightRecorder(sample_frozen_every=N)`` opts into
        a 1-in-N sample of this lane (``surface="warm_sampled"``)."""
        rec = self._recorder                  # one load+test when not tracing
        if rec is not None:
            rec.add(family.name, machine.name, dict(items))
        orec = obs._recorder                  # one load+test when not tracing
        if orec is not None and orec.sample_frozen_every:
            orec.sample_warm(family.name, machine.name, items)
        frozen = self.frozen_plan
        if frozen is not None:
            fn = frozen._fns.get((family, machine.name, items, interpret))
            if fn is not None:
                return fn
            fn = frozen.learn_fn(family, machine.name, items, interpret)
            if fn is not None:
                return fn
        # straight to tiers 1-3: the frozen plan was just consulted (or is
        # absent), re-probing it inside best_variant would be dead work
        cand = self._resolve_tiers(family, machine, dict(items))[0]
        return family.instantiate(cand.plan, cand.assignment,
                                  interpret=interpret,
                                  leaf_index=cand.leaf_index)

    # -- tier 2: precompiled dispatch tables ---------------------------------
    def _try_attach_store(self) -> bool:
        """Late store resolution: ask the resolver (when configured) whether
        an artifact dir has appeared since construction."""
        if self._store_resolver is None:
            return False
        store = self._store_resolver()
        if store is None:
            return False
        self.attach_store(store)
        return True

    def _table(self, family_name: str, machine_name: str
               ) -> Optional[Tuple[Dict[str, Any], Dict[int, Leaf]]]:
        """Load + parse a dispatch table once per (family, machine)."""
        if self.store is None and not self._try_attach_store():
            return None
        tkey = (family_name, machine_name)
        with self._lock:
            if tkey in self._tables:
                return self._tables[tkey]
        parsed = None
        payload = self.store.load_dispatch(family_name, machine_name)
        if payload is not None:
            try:
                parsed = (payload, serde.table_leaves(payload))
            except (serde.ArtifactFormatError, AttributeError, KeyError,
                    TypeError, ValueError):
                parsed = None
        with self._lock:
            self._tables[tkey] = parsed
        return parsed

    @staticmethod
    def _measured_order(table: Dict[str, Any], bucket: str,
                        n_entries: int) -> Optional[List[int]]:
        """Entry order from a tuned table's ``measured_ranks`` section.

        Returns ``None`` (symbolic order) unless the section exists and the
        bucket's ``order`` is a list of unique in-range ints — any malformed
        content degrades to the symbolic ranking, never an error."""
        section = table.get("measured_ranks")
        if not isinstance(section, dict):
            return None
        rec = section.get(bucket)
        if not isinstance(rec, dict):
            return None
        order = rec.get("order")
        if not isinstance(order, list) or not order:
            return None
        try:
            idx = [int(i) for i in order]
        except (TypeError, ValueError):
            return None
        if len(set(idx)) != len(idx) or \
                any(i < 0 or i >= n_entries for i in idx):
            return None
        # entries the tuner never saw keep their symbolic rank at the tail
        seen = set(idx)
        return idx + [i for i in range(n_entries) if i not in seen]

    def _bucket_entries(self, family: FamilySpec,
                        machine: MachineDescription, data: Mapping[str, int]
                        ) -> Optional[Tuple[Dict[str, Any], Dict[int, Leaf],
                                            str, List[Any]]]:
        """Shared tier-2 prologue: load the table, reject stale machine
        bindings, find the data's bucket.  Both the resolution path
        (:meth:`_from_disk`) and the observability path
        (:meth:`rank_source`) go through here so they cannot drift."""
        loaded = self._table(family.name, machine.name)
        if loaded is None:
            return None
        table, leaves = loaded
        if table.get("machine_bindings") != machine.bindings():
            return None                       # stale table for a renamed host
        bucket = bucket_key(data)
        entries = table.get("buckets", {}).get(bucket)
        if not entries:
            return None
        return table, leaves, bucket, entries

    def _from_disk(self, family: FamilySpec, machine: MachineDescription,
                   data: Mapping[str, int],
                   exclude: FrozenSet[CandKey] = frozenset()
                   ) -> Optional[Tuple[Candidate, bool, int]]:
        """Resolve via the precompiled table; ``(candidate, measured,
        rank)`` or ``None``.  ``measured`` flags that a tuned
        (measured-rank) order decided the walk — :class:`DispatchStats`
        reports it; ``rank`` is the winner's position in that walk (0 =
        the bucket's top pick — provenance for the obs decision records).
        ``exclude`` carries runtime-demoted candidate keys
        (:meth:`demote`): the walk skips them like infeasible entries,
        falling down the same ranking; a shortlist that is *entirely*
        excluded returns ``None`` so the cold tier applies its exhaustion
        wrap-around."""
        loaded = self._bucket_entries(family, machine, data)
        if loaded is None:
            return None
        table, leaves, bucket, entries = loaded
        order = self._measured_order(table, bucket, len(entries))
        measured = order is not None
        if order is not None:
            entries = [entries[i] for i in order]
        binding = {**machine.bindings(),
                   **{k: int(v) for k, v in data.items()}}
        for rank, entry in enumerate(entries):  # best first (measured/symbolic)
            try:
                idx = int(entry["leaf_index"])
                asg = {k: int(v) for k, v in entry["assignment"].items()}
                score = float(entry["score"])
            except (AttributeError, KeyError, TypeError, ValueError):
                return None                   # mangled entry => cache miss
            if exclude and (idx, tuple(sorted(asg.items()))) in exclude:
                continue                      # runtime-demoted: next ranked
            leaf = leaves.get(idx)
            if leaf is None:
                return None
            full = {**binding, **asg}
            # fully-bound specialization decides feasibility exactly (and
            # is memoized); only unclassifiable systems pay the exact check
            cs = leaf.constraints.specialize(full)
            if cs.decided:
                infeasible = cs.infeasible
            else:
                infeasible = (leaf.constraints.subs(full).check(samples=64)
                              is Verdict.INCONSISTENT)
            if infeasible:
                continue                      # infeasible for the exact shape
            return (Candidate(leaf_index=idx, plan=leaf.plan,
                              assignment=asg, score=score), measured, rank)
        return None

    def rank_source(self, family: FamilySpec, machine: MachineDescription,
                    data: Mapping[str, int]) -> str:
        """Which ranking would decide this triple at tier 2.

        ``"measured"`` — the loaded table carries a usable measured order
        for the data's bucket; ``"symbolic"`` — a table bucket exists but
        has no (valid) measurement; ``"cold"`` — no table/bucket, tier 3
        would enumerate.  Purely observational (used by serving warm-up
        reports); does not touch the LRU or stats."""
        loaded = self._bucket_entries(family, machine, data)
        if loaded is None:
            return "cold"
        table, _, bucket, entries = loaded
        if self._measured_order(table, bucket, len(entries)) is not None:
            return "measured"
        return "symbolic"

    # -- tier 3 support: disk tree beats in-process rebuild ------------------
    def _tree(self, family: FamilySpec) -> Optional[Sequence[Leaf]]:
        if self.store is None and not self._try_attach_store():
            return None
        with self._lock:
            if family.name in self._trees:
                return self._trees[family.name]
        tree = self.store.load_tree(family.name)
        with self._lock:
            self._trees[family.name] = tree
        return tree


# ---------------------------------------------------------------------------
# Process-wide default cache (what core.select.best_variant routes through).
# ---------------------------------------------------------------------------
_default_cache: Optional[DispatchCache] = None
_default_lock = threading.Lock()


def _resolve_env_store() -> Optional[ArtifactStore]:
    import os
    root = os.environ.get("REPRO_ARTIFACT_DIR", "artifacts")
    return ArtifactStore(root) if os.path.isdir(root) else None


def get_default_cache() -> DispatchCache:
    """The process-wide cache, creating it on first touch.

    The auto-created default carries a store *resolver*: while no store is
    attached, the artifact dir (``REPRO_ARTIFACT_DIR`` or ``./artifacts``)
    is re-probed whenever a resolution reaches tier 2/3 — an artifact dir
    compiled or an env var exported *after* the first dispatch is picked
    up, not silently ignored forever.  A cache installed explicitly via
    :func:`set_default_cache` keeps whatever store the caller chose — tests
    rely on a store-less cache *staying* store-less for isolation.

    Double-checked locking: once a cache is installed, this is a lock-free
    module-global read (GIL-atomic) — it sits on the warm op path, where
    the old per-call lock acquire was measurable."""
    global _default_cache
    cache = _default_cache
    if cache is not None:
        return cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = DispatchCache(store=_resolve_env_store(),
                                           store_resolver=_resolve_env_store)
        return _default_cache


def set_default_cache(cache: Optional[DispatchCache]) -> None:
    """Install (or with ``None`` reset) the process-wide dispatch cache.

    ``None`` re-arms the environment probe: the next ``get_default_cache``
    builds a fresh default that resolves its store from the environment.
    An explicit cache is installed as-is (no resolver is grafted on)."""
    global _default_cache
    with _default_lock:
        _default_cache = cache
