"""O(1) runtime dispatch over precompiled case discussions.

``DispatchCache.best_variant`` resolves a (family, machine, data) triple
through three tiers:

  1. **memory LRU** — exact-key memo of resolved :class:`Candidate`s; a
     recurring triple (the serving steady state) costs one dict lookup;
  2. **disk artifact** — a per-machine dispatch table compiled offline
     (:mod:`repro.artifacts.compile`): leaves pre-specialized against the
     machine bindings and candidates pre-ranked per data-shape *bucket*
     (dims rounded up to powers of two).  On a bucket hit the ranked list is
     re-validated against the *exact* data — a constant number of constraint
     substitutions, no enumeration — so an off-grid shape still gets a sound
     answer from the precompiled ranking;
  3. **cold rebuild** — full ``rank_candidates`` over the tree (itself
     loaded from the tree artifact when present, rebuilt in-process when
     not).

Within tier 2, a FORMAT_VERSION-2 table may carry a ``measured_ranks``
section written by ``scripts/tune_artifacts.py`` (see :mod:`repro.tuning`):
per bucket, the candidate order observed on real hardware.  When present
and well-formed it *reorders* the shortlist walk — measured rank beats the
symbolic score — but it can never add candidates; feasibility still comes
from the leaf constraints alone.

Invariants this module maintains (tests enforce them):

- **cache-miss-never-error** — a missing, unreadable, version-mismatched,
  or field-mangled table (including a malformed ``measured_ranks`` or
  ``calibration`` section) degrades to the next tier; no artifact content
  can raise out of ``best_variant``;
- **soundness** — tier 2 never invents feasibility: every candidate it
  returns passes the same leaf-constraint check the cold path applies; if
  the whole precompiled shortlist fails for the exact data, we fall through
  to tier 3;
- **parity without tuning** — a table with no ``measured_ranks`` section
  resolves exactly as the symbolic cold path would (asserted by the
  artifact/tuning test suites).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.constraints import Verdict
from ..core.params import MachineDescription
from ..core.plan import FamilySpec, Leaf
from ..core.select import Candidate, rank_candidates
from . import serde
from .store import ArtifactStore

DispatchKey = Tuple[str, str, Tuple[Tuple[str, int], ...]]


def bucket_key(data: Mapping[str, int]) -> str:
    """Canonical data-shape bucket: each dim rounded up to a power of two."""
    parts = []
    for k in sorted(data):
        v = max(1, int(data[k]))
        parts.append(f"{k}{1 << (v - 1).bit_length()}")
    return "|".join(parts)


@dataclass
class DispatchStats:
    memory_hits: int = 0
    disk_hits: int = 0
    cold_builds: int = 0
    measured_hits: int = 0        # disk hits served in measured (tuned) order

    def reset(self) -> None:
        self.memory_hits = self.disk_hits = self.cold_builds = 0
        self.measured_hits = 0

    def as_dict(self) -> Dict[str, int]:
        return {"memory_hits": self.memory_hits, "disk_hits": self.disk_hits,
                "cold_builds": self.cold_builds,
                "measured_hits": self.measured_hits}


class DispatchCache:
    """Memory LRU -> disk artifact -> cold rebuild, per paper's load-time split.

    Thread notes: the LRU, memoized tables/trees, and stats are lock-
    protected; concurrent misses on the same uncached triple may duplicate
    the (idempotent) tier-2/3 work, with one winner filling the LRU."""

    def __init__(self, store: Optional[ArtifactStore] = None,
                 maxsize: int = 4096):
        self.store = store
        self.maxsize = maxsize
        self.stats = DispatchStats()
        # key -> (candidate, source) where source records which ranking
        # decided the original resolution: "measured" | "symbolic" | "cold"
        self._lru: "OrderedDict[DispatchKey, Tuple[Candidate, str]]" = \
            OrderedDict()
        # (family, machine) -> (raw payload, leaves parsed once) or None
        self._tables: Dict[Tuple[str, str],
                           Optional[Tuple[Dict[str, Any],
                                          Dict[int, Leaf]]]] = {}
        self._trees: Dict[str, Optional[List[Leaf]]] = {}
        self._lock = threading.Lock()

    # -- public API ----------------------------------------------------------
    def best_variant(self, family: FamilySpec, machine: MachineDescription,
                     data: Mapping[str, int]) -> Candidate:
        return self.best_variant_with_source(family, machine, data)[0]

    def best_variant_with_source(self, family: FamilySpec,
                                 machine: MachineDescription,
                                 data: Mapping[str, int]
                                 ) -> Tuple[Candidate, str]:
        """Resolve, also reporting which ranking decided the candidate:
        ``"measured"`` (tuned table order), ``"symbolic"`` (precompiled
        offline ranking), or ``"cold"`` (tier-3 rebuild).  A memory hit
        returns the source recorded when the triple was first resolved, so
        attribution is race-free under concurrent callers."""
        key: DispatchKey = (family.name, machine.name,
                            tuple(sorted((k, int(v)) for k, v in data.items())))
        with self._lock:
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                self.stats.memory_hits += 1
                return hit

        hit2 = self._from_disk(family, machine, data)
        if hit2 is None:
            cold = rank_candidates(family, machine, data,
                                   leaves=self._tree(family))[0]

        with self._lock:
            if hit2 is not None:
                cand, measured = hit2
                source = "measured" if measured else "symbolic"
                self.stats.disk_hits += 1
                if measured:
                    self.stats.measured_hits += 1
            else:
                self.stats.cold_builds += 1
                cand, source = cold, "cold"
            self._lru[key] = (cand, source)
            self._lru.move_to_end(key)
            while len(self._lru) > self.maxsize:
                self._lru.popitem(last=False)
        return cand, source

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self._tables.clear()
            self._trees.clear()
            self.stats.reset()

    def __len__(self) -> int:
        return len(self._lru)

    # -- tier 2: precompiled dispatch tables ---------------------------------
    def _table(self, family_name: str, machine_name: str
               ) -> Optional[Tuple[Dict[str, Any], Dict[int, Leaf]]]:
        """Load + parse a dispatch table once per (family, machine)."""
        if self.store is None:
            return None
        tkey = (family_name, machine_name)
        with self._lock:
            if tkey in self._tables:
                return self._tables[tkey]
        parsed = None
        payload = self.store.load_dispatch(family_name, machine_name)
        if payload is not None:
            try:
                parsed = (payload, serde.table_leaves(payload))
            except (serde.ArtifactFormatError, AttributeError, KeyError,
                    TypeError, ValueError):
                parsed = None
        with self._lock:
            self._tables[tkey] = parsed
        return parsed

    @staticmethod
    def _measured_order(table: Dict[str, Any], bucket: str,
                        n_entries: int) -> Optional[List[int]]:
        """Entry order from a tuned table's ``measured_ranks`` section.

        Returns ``None`` (symbolic order) unless the section exists and the
        bucket's ``order`` is a list of unique in-range ints — any malformed
        content degrades to the symbolic ranking, never an error."""
        section = table.get("measured_ranks")
        if not isinstance(section, dict):
            return None
        rec = section.get(bucket)
        if not isinstance(rec, dict):
            return None
        order = rec.get("order")
        if not isinstance(order, list) or not order:
            return None
        try:
            idx = [int(i) for i in order]
        except (TypeError, ValueError):
            return None
        if len(set(idx)) != len(idx) or \
                any(i < 0 or i >= n_entries for i in idx):
            return None
        # entries the tuner never saw keep their symbolic rank at the tail
        seen = set(idx)
        return idx + [i for i in range(n_entries) if i not in seen]

    def _bucket_entries(self, family: FamilySpec,
                        machine: MachineDescription, data: Mapping[str, int]
                        ) -> Optional[Tuple[Dict[str, Any], Dict[int, Leaf],
                                            str, List[Any]]]:
        """Shared tier-2 prologue: load the table, reject stale machine
        bindings, find the data's bucket.  Both the resolution path
        (:meth:`_from_disk`) and the observability path
        (:meth:`rank_source`) go through here so they cannot drift."""
        loaded = self._table(family.name, machine.name)
        if loaded is None:
            return None
        table, leaves = loaded
        if table.get("machine_bindings") != machine.bindings():
            return None                       # stale table for a renamed host
        bucket = bucket_key(data)
        entries = table.get("buckets", {}).get(bucket)
        if not entries:
            return None
        return table, leaves, bucket, entries

    def _from_disk(self, family: FamilySpec, machine: MachineDescription,
                   data: Mapping[str, int]
                   ) -> Optional[Tuple[Candidate, bool]]:
        """Resolve via the precompiled table; ``(candidate, measured)`` or
        ``None``.  ``measured`` flags that a tuned (measured-rank) order
        decided the walk — :class:`DispatchStats` reports it."""
        loaded = self._bucket_entries(family, machine, data)
        if loaded is None:
            return None
        table, leaves, bucket, entries = loaded
        order = self._measured_order(table, bucket, len(entries))
        measured = order is not None
        if order is not None:
            entries = [entries[i] for i in order]
        binding = {**machine.bindings(),
                   **{k: int(v) for k, v in data.items()}}
        for entry in entries:                 # best first (measured/symbolic)
            try:
                idx = int(entry["leaf_index"])
                asg = {k: int(v) for k, v in entry["assignment"].items()}
                score = float(entry["score"])
            except (AttributeError, KeyError, TypeError, ValueError):
                return None                   # mangled entry => cache miss
            leaf = leaves.get(idx)
            if leaf is None:
                return None
            full = {**binding, **asg}
            # fully-bound specialization decides feasibility exactly (and
            # is memoized); only unclassifiable systems pay the exact check
            cs = leaf.constraints.specialize(full)
            if cs.decided:
                infeasible = cs.infeasible
            else:
                infeasible = (leaf.constraints.subs(full).check(samples=64)
                              is Verdict.INCONSISTENT)
            if infeasible:
                continue                      # infeasible for the exact shape
            return Candidate(leaf_index=idx, plan=leaf.plan,
                             assignment=asg, score=score), measured
        return None

    def rank_source(self, family: FamilySpec, machine: MachineDescription,
                    data: Mapping[str, int]) -> str:
        """Which ranking would decide this triple at tier 2.

        ``"measured"`` — the loaded table carries a usable measured order
        for the data's bucket; ``"symbolic"`` — a table bucket exists but
        has no (valid) measurement; ``"cold"`` — no table/bucket, tier 3
        would enumerate.  Purely observational (used by serving warm-up
        reports); does not touch the LRU or stats."""
        loaded = self._bucket_entries(family, machine, data)
        if loaded is None:
            return "cold"
        table, _, bucket, entries = loaded
        if self._measured_order(table, bucket, len(entries)) is not None:
            return "measured"
        return "symbolic"

    # -- tier 3 support: disk tree beats in-process rebuild ------------------
    def _tree(self, family: FamilySpec) -> Optional[Sequence[Leaf]]:
        if self.store is None:
            return None
        with self._lock:
            if family.name in self._trees:
                return self._trees[family.name]
        tree = self.store.load_tree(family.name)
        with self._lock:
            self._trees[family.name] = tree
        return tree


# ---------------------------------------------------------------------------
# Process-wide default cache (what core.select.best_variant routes through).
# ---------------------------------------------------------------------------
_default_cache: Optional[DispatchCache] = None
_default_lock = threading.Lock()


def get_default_cache() -> DispatchCache:
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            import os
            root = os.environ.get("REPRO_ARTIFACT_DIR", "artifacts")
            store = ArtifactStore(root) if os.path.isdir(root) else None
            _default_cache = DispatchCache(store=store)
        return _default_cache


def set_default_cache(cache: Optional[DispatchCache]) -> None:
    """Install (or with ``None`` reset) the process-wide dispatch cache."""
    global _default_cache
    with _default_lock:
        _default_cache = cache
