"""Filesystem artifact store: where compiled case discussions live.

Layout (all JSON, canonical bytes from :mod:`repro.artifacts.serde`):

    <root>/<family>/tree-v<V>-<axioms_key>.json
    <root>/<family>/dispatch-v<V>-<machine>.json

``root`` resolution: explicit argument > ``REPRO_ARTIFACT_DIR`` env var >
``./artifacts``.  Loads are forgiving by design — a missing file, unreadable
JSON, or a format-version mismatch all return ``None`` (cache miss, caller
rebuilds); only writes raise.  That is the version policy the format needs:
old runtimes keep working against new trees by rebuilding, never by crashing.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Sequence

from ..core.constraints import Constraint
from ..core.plan import Leaf
# chaos-drill hooks only: repro.runtime.faults is jax-free and its site
# checks cost one module-global load when no injector is armed
from ..runtime import faults
from . import serde

_ENV_ROOT = "REPRO_ARTIFACT_DIR"
_DEFAULT_ROOT = "artifacts"


def atomic_write_text(path: Path, text: str) -> Path:
    """Write via mkstemp + rename so a concurrent reader never sees a torn
    artifact (shared by every artifact store, incl. repro.plans)."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def read_json_dict(path: Path,
                   fault_site: str = "artifact.read"
                   ) -> Optional[Dict[str, Any]]:
    """Forgiving read: a missing file, unreadable JSON, or a non-dict
    payload returns ``None`` (cache miss), never raises.

    ``fault_site`` names this read for the chaos drills
    (:mod:`repro.runtime.faults`): an armed injector can raise an I/O
    failure mid-open or corrupt the bytes before parsing (torn truncation /
    NUL garbling).  Both land inside the ``except`` below — the drills
    *prove* the forgiving-read policy rather than bypass it.  Only a
    :class:`~repro.runtime.faults.FatalFault` escapes, by design."""
    try:
        with open(path) as f:
            text = f.read()
        # the one injection hook: raising kinds (io) raise from inside it,
        # byte kinds (torn/garble) mangle the text before parsing
        payload = json.loads(faults.corrupt_text(fault_site, text))
    except faults.FatalFault:
        raise
    except (OSError, ValueError):
        return None
    return payload if isinstance(payload, dict) else None


class ArtifactStore:
    def __init__(self, root: str | os.PathLike | None = None):
        self.root = Path(root or os.environ.get(_ENV_ROOT, _DEFAULT_ROOT))

    # -- paths ---------------------------------------------------------------
    def tree_path(self, family_name: str,
                  axioms: Sequence[Constraint] = ()) -> Path:
        key = serde.axioms_key(axioms)
        return (self.root / family_name /
                f"tree-v{serde.FORMAT_VERSION}-{key}.json")

    def dispatch_path(self, family_name: str, machine_name: str) -> Path:
        return (self.root / family_name /
                f"dispatch-v{serde.FORMAT_VERSION}-{machine_name}.json")

    # -- low-level IO --------------------------------------------------------
    def _write(self, path: Path, payload: Mapping[str, Any]) -> Path:
        return atomic_write_text(path, serde.dumps(payload))

    def _read(self, path: Path) -> Optional[Dict[str, Any]]:
        payload = read_json_dict(path)
        if payload is None:
            return None
        if payload.get("format") != serde.FORMAT_VERSION:
            return None                      # version mismatch == cache miss
        return payload

    # -- trees ---------------------------------------------------------------
    def save_tree(self, family_name: str, leaves: Sequence[Leaf],
                  axioms: Sequence[Constraint] = ()) -> Path:
        payload = serde.tree_to_obj(family_name, leaves, axioms)
        return self._write(self.tree_path(family_name, axioms), payload)

    def load_tree(self, family_name: str,
                  axioms: Sequence[Constraint] = ()) -> Optional[List[Leaf]]:
        payload = self._read(self.tree_path(family_name, axioms))
        if payload is None or payload.get("kind") != "tree":
            return None
        try:
            return serde.obj_to_tree(payload)
        except (serde.ArtifactFormatError, KeyError, TypeError, ValueError):
            return None

    # -- dispatch tables -----------------------------------------------------
    def save_dispatch(self, payload: Mapping[str, Any]) -> Path:
        if payload.get("kind") != "dispatch":
            raise serde.ArtifactFormatError("payload is not a dispatch table")
        return self._write(
            self.dispatch_path(payload["family"], payload["machine"]), payload)

    def load_dispatch(self, family_name: str,
                      machine_name: str) -> Optional[Dict[str, Any]]:
        payload = self._read(self.dispatch_path(family_name, machine_name))
        if payload is None or payload.get("kind") != "dispatch":
            return None
        return payload

    def __repr__(self) -> str:
        return f"ArtifactStore({str(self.root)!r})"
