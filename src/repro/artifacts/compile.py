"""Offline artifact compiler — the machine-free step of the paper, persisted.

``compile_family`` runs comprehensive optimization once, saves the tree, and
for each target machine emits a *dispatch table*: the machine-consistent
leaves plus, per representative data-shape bucket, the top-k candidates
pre-ranked by the offline performance model.  ``compile_all`` sweeps every
registered kernel family.  This is what ``scripts/compile_artifacts.py``
drives; CI runs it as a smoke step so a schema regression fails the build,
not a deploy.

Kernel families are imported lazily (they pull in jax/pallas); the serde and
store layers stay importable on a bare interpreter.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from ..core.comprehensive import comprehensive_tree
from ..core.params import MACHINES, MachineDescription
from ..core.plan import FamilySpec
from ..core.select import STATS, rank_candidates, specialize
from . import serde
from .dispatch import bucket_key
from .store import ArtifactStore

# Representative data shapes per family: the pow-2 grid serving traffic
# actually buckets into.  Off-grid shapes still resolve (dispatch re-validates
# against exact data); on-grid shapes hit the precompiled ranking directly.
_SQUARES = (512, 1024, 2048, 4096)
DEFAULT_DATA_GRIDS: Dict[str, List[Dict[str, int]]] = {
    "matmul": ([{"M": n, "N": n, "K": n} for n in _SQUARES]
               + [{"M": 256, "N": 4096, "K": 1024},
                  {"M": 4096, "N": 256, "K": 1024}]),
    "matadd": [{"M": n, "N": n} for n in _SQUARES],
    "transpose": [{"M": n, "N": n} for n in _SQUARES],
    "jacobi1d": [{"N": n} for n in (1 << 12, 1 << 15, 1 << 18, 1 << 21)],
    "flash_attention": [{"SQ": sq, "HD": hd}
                        for sq in (1024, 4096, 8192, 32768)
                        for hd in (64, 128)],
    "ssd_scan": [{"SQ": sq, "HD": 64, "STATE": 128}
                 for sq in (1024, 4096, 16384)],
}


def registered_families() -> Dict[str, FamilySpec]:
    from ..kernels.ops import FAMILIES        # lazy: imports jax/pallas
    return dict(FAMILIES)


def build_dispatch_table(family: FamilySpec, machine: MachineDescription,
                         shapes: Sequence[Mapping[str, int]],
                         top_k: int = 8) -> Dict[str, Any]:
    """Specialize the family tree for one machine; pre-rank per bucket."""
    leaves = comprehensive_tree(family)
    kept = specialize(leaves, machine, {})    # machine-consistent leaves
    kept_indices = {i for i, _, _ in kept}

    buckets: Dict[str, List[Dict[str, Any]]] = {}
    for data in shapes:
        key = bucket_key(data)
        if key in buckets:
            continue
        try:
            ranked = rank_candidates(family, machine, data, leaves=leaves)
        except ValueError:
            buckets[key] = []                 # nothing feasible at this shape
            continue
        buckets[key] = [
            {"leaf_index": c.leaf_index,
             "assignment": dict(c.assignment),
             "score": float(c.score)}
            for c in ranked[:top_k] if c.leaf_index in kept_indices
        ]
    # leaves keyed by their index in the *full* tree, so a disk-served
    # Candidate carries the same leaf_index the cold path would produce
    return {
        "format": serde.FORMAT_VERSION,
        "kind": "dispatch",
        "family": family.name,
        "machine": machine.name,
        "machine_bindings": machine.bindings(),
        "leaves": {str(i): serde.leaf_to_obj(leaves[i])
                   for i in sorted(kept_indices)},
        "buckets": buckets,
        "top_k": top_k,
    }


def compile_family(family: FamilySpec, store: ArtifactStore,
                   machines: Optional[Iterable[MachineDescription]] = None,
                   shapes: Optional[Sequence[Mapping[str, int]]] = None,
                   top_k: int = 8, quick: bool = False) -> Dict[str, Any]:
    """Tree + per-machine dispatch tables for one family.  Returns a report.

    ``quick`` compiles a single data-shape bucket (CI smoke: exercises the
    full pipeline without sweeping the whole grid)."""
    t0 = time.perf_counter()
    leaves = comprehensive_tree(family)
    tree_path = store.save_tree(family.name, leaves)
    report: Dict[str, Any] = {
        "family": family.name,
        "leaves": len(leaves),
        "tree_path": str(tree_path),
        "tree_digest": serde.digest(serde.tree_to_obj(family.name, leaves)),
        "dispatch": {},
    }
    shapes = shapes if shapes is not None else \
        DEFAULT_DATA_GRIDS.get(family.name, [])
    if quick:
        shapes = shapes[:1]
    rows0, calls0 = STATS.rows_screened, STATS.enumerate_calls
    for machine in (machines if machines is not None else MACHINES.values()):
        tm = time.perf_counter()
        table = build_dispatch_table(family, machine, shapes, top_k=top_k)
        path = store.save_dispatch(table)
        report["dispatch"][machine.name] = {
            "path": str(path),
            "kept_leaves": len(table["leaves"]),
            "buckets": len(table["buckets"]),
            "seconds": round(time.perf_counter() - tm, 3),
        }
    report["seconds"] = round(time.perf_counter() - t0, 3)
    report["enumerate_calls"] = STATS.enumerate_calls - calls0
    report["rows_screened"] = STATS.rows_screened - rows0
    return report


def compile_all(store: ArtifactStore,
                families: Optional[Iterable[str]] = None,
                machines: Optional[Iterable[MachineDescription]] = None,
                top_k: int = 8, quick: bool = False) -> List[Dict[str, Any]]:
    registry = registered_families()
    names = list(families) if families else sorted(registry)
    reports = []
    for name in names:
        if name not in registry:
            raise KeyError(
                f"unknown kernel family {name!r}; have {sorted(registry)}")
        reports.append(
            compile_family(registry[name], store, machines=machines,
                           top_k=top_k, quick=quick))
    return reports
