"""Deterministic synthetic data pipeline (stateless, shard-local, prefetch)."""
from .pipeline import DataConfig, PrefetchIterator, SyntheticLM, make_pipeline

__all__ = ["DataConfig", "PrefetchIterator", "SyntheticLM", "make_pipeline"]
