"""Deterministic synthetic token pipeline.

Design requirements at 1000-node scale:

* **Stateless addressing** — ``batch_at(step)`` is a pure function of
  ``(seed, step)``, so a restarted or elastically re-meshed job resumes the
  exact data order from the checkpointed step with no iterator state to
  save (the checkpoint stores only the integer step).
* **Shard-local generation** — each host materializes only its slice of the
  global batch (``host_slice``); nothing global is ever allocated, so the
  pipeline scales to any global batch size.
* **Learnable distribution** — tokens follow a Zipfian unigram mixed with a
  deterministic bigram successor rule, so the LM loss has signal to descend
  (integration tests assert loss decreases on this stream).
* **Prefetch** — a small background thread keeps ``prefetch`` batches ahead
  of the training loop, overlapping host-side generation with device steps.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2
    bigram_fraction: float = 0.5     # fraction of positions forced by bigram


class SyntheticLM:
    """Zipf + bigram synthetic language."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self.probs = probs / probs.sum()
        # deterministic successor table: bigram rule t -> (a*t + c) % vocab
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        self.succ_mul = int(rng.integers(3, 97)) * 2 + 1       # odd => bijective
        self.succ_add = int(rng.integers(0, cfg.vocab))

    def successor(self, tok: np.ndarray) -> np.ndarray:
        return (tok * self.succ_mul + self.succ_add) % self.cfg.vocab

    def batch_at(self, step: int, *, host_slice: slice | None = None
                 ) -> Dict[str, np.ndarray]:
        """Batch for ``step`` (pure function).  Returns {tokens, labels}.

        ``host_slice`` selects the rows this host owns; default is the full
        global batch (single-host testing).
        """
        cfg = self.cfg
        sl = host_slice or slice(0, cfg.global_batch)
        rows = range(sl.start, min(sl.stop, cfg.global_batch))
        n = len(rows)
        out = np.empty((n, cfg.seq_len + 1), dtype=np.int64)
        for i, r in enumerate(rows):
            rng = np.random.default_rng((cfg.seed, step, r))
            seq = rng.choice(cfg.vocab, size=cfg.seq_len + 1, p=self.probs)
            use_bigram = rng.random(cfg.seq_len) < cfg.bigram_fraction
            # sequential chain: bigram positions continue from the *final*
            # previous token, so labels really are predictable at the
            # configured rate (tests/test_substrate.py checks the rate).
            # vectorized per run: within a bigram run of length k starting
            # after a free token t0, token j is successor^j(t0); iterate
            # runs via simple loop over breakpoints (few per row).
            free = np.flatnonzero(~use_bigram)
            pos = 0
            for end in list(free) + [cfg.seq_len]:
                # positions pos..end-1 are bigram-forced
                for t in range(pos, end):
                    seq[t + 1] = (seq[t] * self.succ_mul + self.succ_add) \
                        % cfg.vocab
                pos = end + 1
            out[i] = seq
        out = out.astype(np.int32)
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}


class PrefetchIterator:
    """Background-thread prefetch over ``batch_at`` starting at ``step0``."""

    def __init__(self, ds: SyntheticLM, step0: int = 0, prefetch: int = 2,
                 host_slice: slice | None = None):
        self.ds = ds
        self.step = step0
        self.host_slice = host_slice
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = self.ds.batch_at(s, host_slice=self.host_slice)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        return self

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.t.join(timeout=2)


def make_pipeline(vocab: int, seq_len: int, global_batch: int, *,
                  seed: int = 0, step0: int = 0,
                  host_index: int = 0, host_count: int = 1,
                  prefetch: int = 2) -> PrefetchIterator:
    """Standard entry point: shard rows across hosts, prefetch in background."""
    cfg = DataConfig(vocab=vocab, seq_len=seq_len, global_batch=global_batch,
                     seed=seed)
    per_host = global_batch // host_count
    sl = slice(host_index * per_host, (host_index + 1) * per_host)
    return PrefetchIterator(SyntheticLM(cfg), step0=step0, prefetch=prefetch,
                            host_slice=sl)
