"""Optimization strategies O_1..O_w (paper §3.4, §5).

The paper's implementation uses four strategy kinds — (i) register-pressure
reduction (3 levels), (ii) thread-granularity control, (iii) CSE (2 levels),
(iv) shared/local-memory caching.  We keep the same taxonomy with TPU
semantics:

  reduce_pressure_L{1,2,3}  : rematerialize / split the accumulation tile so
                              fewer live lane-values are held per grid step
                              (paper: fewer registers per thread).
  reduce_granularity        : shrink the per-grid-step output grain ``s``
                              (paper: reduce work per thread).
  cse_L{1,2}                : common-subexpression elimination on the index
                              arithmetic of the emitted kernel body.
  cache_vmem                : stage operand tiles in VMEM via BlockSpec
                              (paper: __shared__ staging via ``cache(a)``).

Each strategy is semantics-preserving on the plan (code soundness (ii)) and
idempotent per level (the paper's idempotence assumption): families encode
levels as monotone flags, so re-application at the same level is a no-op.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .plan import KernelPlan

ApplyFn = Callable[[KernelPlan], Optional[KernelPlan]]


@dataclass(frozen=True)
class Strategy:
    """A named semantics-preserving plan transformation."""

    name: str
    apply: ApplyFn            # returns transformed plan, or None if not applicable
    doc: str = ""

    def __call__(self, plan: KernelPlan) -> Optional[KernelPlan]:
        return self.apply(plan)


# ---- generic flag-level helpers shared by kernel families -------------------

def level_strategy(name: str, flag: str, level: int, doc: str = "") -> Strategy:
    """Strategy that raises ``flag`` to ``level`` (idempotent, monotone)."""

    def apply(plan: KernelPlan) -> Optional[KernelPlan]:
        cur = plan.flags.get(flag, 0)
        if cur >= level:
            return None                      # idempotence: nothing further
        return plan.with_flag(flag, level, note=f"{name}")

    return Strategy(name, apply, doc)


def toggle_strategy(name: str, flag: str, value=True, doc: str = "") -> Strategy:
    def apply(plan: KernelPlan) -> Optional[KernelPlan]:
        if plan.flags.get(flag) == value:
            return None
        return plan.with_flag(flag, value, note=f"{name}")

    return Strategy(name, apply, doc)
