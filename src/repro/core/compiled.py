"""Compiled evaluation for the symbolic core (specialize-once, batch-eval).

The comprehensive tree is built once per family with every parameter
symbolic; resolving it for a concrete (machine, data) binding used to pay
per-candidate exact ``Fraction`` substitution — seconds per cold dispatch.
This module lowers the symbolic objects to flat array programs the way
KLARAPTOR (arXiv:1911.02373) compiles its rational programs before sweeping
the launch-parameter lattice:

``CompiledPoly``
    a polynomial lowered to parallel (coefficient, monomial) arrays with a
    NumPy batched evaluator, plus the original :class:`Poly` for the
    exact-Fraction single-point fallback.  Coefficients are scaled to
    integers (lcm of denominators), so over integer assignments the float64
    evaluation is *exact* whenever a precomputed magnitude bound certifies
    every intermediate stays below 2**53.

``CompiledSystem``
    a constraint system partial-evaluated against a machine+data binding
    *once*, with residual atoms classified (constant / row-parameter /
    measure-linear / general) and per-program-parameter integer bounds
    precomputed.  ``feasible_rows`` then decides a whole cross-product of
    program-parameter assignments in a handful of vectorized passes,
    replicating exactly the inconsistency proofs of
    :meth:`ConstraintSystem.check` (constant refutation + interval-box
    emptiness); rows it cannot certify fall back to the exact path.

Variable-domain convention (paper hypothesis H1): names starting with
``P_`` are performance measures — rationals in ``[0, 1]``; every other
variable ranges over the non-negative integers.  See
:func:`repro.core.constraints.is_integer_var`.

Invariants (docs/architecture.md restates these; tests enforce them):

- **float64-exactness certificate** — the vectorized evaluators only trust
  a float64 result when the precomputed magnitude bound proves every
  intermediate stays below 2**53; anything the certificate cannot cover
  runs the exact ``Fraction`` fallback.  Speed never changes an answer.
- **screen parity** — ``CompiledSystem.feasible_rows`` replicates exactly
  the INCONSISTENT proofs of :meth:`ConstraintSystem.check` (constant
  refutation + interval-box emptiness), nothing more; the per-candidate
  reference loop remains the parity oracle
  (``use_compiled=False`` / ``REPRO_COMPILED=0``,
  tests/test_select_parity.py).
- **no semantic drift without a version bump** — any change that alters a
  canonical tree's bytes (e.g. a new bound-tightening rule) must bump
  ``repro.artifacts.serde.FORMAT_VERSION`` (ROADMAP policy).
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict
from fractions import Fraction
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .constraints import _DEFAULT_HI, ConstraintSystem, Rel, is_integer_var
from .polynomial import Monomial, Poly

# float64 represents every integer with |x| < 2**53 exactly; products/sums
# certified below this bound are exact integer arithmetic.
_EXACT_LIMIT = 1 << 53


class CompiledPoly:
    """A Poly lowered to a flat coefficient/monomial array program.

    ``scale`` is an integer multiple of the lcm of coefficient denominators;
    the *scaled* evaluators compute ``scale * poly(x)``, which is an integer
    for integer assignments.  Two CompiledPolys built with a shared scale
    (see :func:`compile_pair`) can be compared/cross-multiplied exactly.
    """

    __slots__ = ("poly", "names", "monos", "coeffs_int", "coeffs", "scale")

    def __init__(self, poly: Poly, scale: Optional[int] = None):
        self.poly = poly
        self.names = tuple(sorted(poly.variables()))
        denom = 1
        for c in poly.terms.values():
            denom = math.lcm(denom, c.denominator)
        if scale is None:
            scale = denom
        elif scale % denom:
            raise ValueError(f"scale {scale} incompatible with lcm {denom}")
        self.scale = scale
        monos = tuple(sorted(poly.terms))
        self.monos: Tuple[Monomial, ...] = monos
        self.coeffs_int = tuple(
            int(poly.terms[m] * scale) for m in monos)
        # float64 image of the scaled coefficients; exactness of batched
        # evaluation is certified via max_abs_scaled, never assumed here
        self.coeffs = np.array([float(c) for c in self.coeffs_int]
                               if monos else [], dtype=np.float64)

    # -- batched evaluation --------------------------------------------------
    def eval_batch_scaled(self, cols: Mapping[str, np.ndarray]) -> np.ndarray:
        """``scale * poly`` over a batch; ``cols`` maps var -> array/scalar."""
        acc: np.ndarray | float = 0.0
        for coeff, mono in zip(self.coeffs, self.monos):
            term: np.ndarray | float = coeff
            for var, exp in mono:
                if var not in cols:
                    raise KeyError(f"unbound variable {var!r} in {self.poly}")
                col = cols[var]
                term = term * (col ** exp if exp > 1 else col)
            acc = acc + term
        return np.asarray(acc, dtype=np.float64)

    def eval_batch(self, cols: Mapping[str, np.ndarray]) -> np.ndarray:
        """True (unscaled) float64 values for a batch of assignments."""
        out = self.eval_batch_scaled(cols)
        return out / self.scale if self.scale != 1 else out

    # -- exactness certificate ----------------------------------------------
    def max_abs_scaled(self, maxvals: Mapping[str, int]) -> int:
        """Upper bound (exact int) on |scale * poly| over ``|var| <= maxval``.

        Uses ``max(|maxval|, 1)`` per variable so the bound also dominates
        every intermediate term/partial sum: below 2**53 the float64 batched
        evaluation over integer columns is exact integer arithmetic."""
        bound = 0
        for c, mono in zip(self.coeffs_int, self.monos):
            t = abs(c)
            for var, exp in mono:
                t *= max(abs(int(maxvals[var])), 1) ** exp
            bound += t
        return bound

    # -- exact fallback ------------------------------------------------------
    def eval_exact(self, assignment: Mapping[str, object]) -> Fraction:
        return self.poly.eval(assignment)

    def __repr__(self) -> str:
        return f"CompiledPoly({self.poly!r}, scale={self.scale})"


def compile_pair(a: Poly, b: Poly) -> Tuple[CompiledPoly, CompiledPoly]:
    """Compile two polys with one shared scale (exact cross-comparisons)."""
    denom = 1
    for p in (a, b):
        for c in p.terms.values():
            denom = math.lcm(denom, c.denominator)
    return CompiledPoly(a, scale=denom), CompiledPoly(b, scale=denom)


# ---------------------------------------------------------------------------
# Residual-atom classification
# ---------------------------------------------------------------------------

class _RowAtom:
    """Residual atom over row (program) variables only: sign test per row."""

    __slots__ = ("cpoly", "rel")

    def __init__(self, cpoly: CompiledPoly, rel: Rel):
        self.cpoly = cpoly
        self.rel = rel


class _MeasureAtom:
    """Residual atom ``k(row) * m + c(row) REL 0`` for one measure var m.

    ``k`` and ``c`` share one scale, so the bound ``-c/k`` is a ratio of the
    scaled integer evaluations with the scale cancelled."""

    __slots__ = ("var", "k", "c", "rel")

    def __init__(self, var: str, k: CompiledPoly, c: CompiledPoly, rel: Rel):
        self.var = var
        self.k = k
        self.c = c
        self.rel = rel


def _const_holds(c: Fraction, rel: Rel) -> bool:
    if rel is Rel.GE:
        return c >= 0
    if rel is Rel.GT:
        return c > 0
    return c == 0


def _rel_mask(vals: np.ndarray, rel: Rel) -> np.ndarray:
    if rel is Rel.GE:
        return vals >= 0
    if rel is Rel.GT:
        return vals > 0
    return vals == 0


class _Interval:
    """Exact rational interval with strict flags, mirroring Box semantics:
    lower default 0 (non-strict), upper default ``_DEFAULT_HI``."""

    __slots__ = ("lo", "hi", "lo_strict", "hi_strict")

    def __init__(self):
        self.lo = Fraction(0)
        self.hi = Fraction(_DEFAULT_HI)
        self.lo_strict = False
        self.hi_strict = False

    def add(self, k: Fraction, c: Fraction, rel: Rel, integer: bool) -> None:
        """Tighten with ``k*m + c REL 0`` (k != 0)."""
        bound = -c / k
        if rel is Rel.EQ:
            self._raise_lo(bound, False)
            self._lower_hi(bound, False)
        elif k > 0:
            if rel is Rel.GT and integer:
                self._raise_lo(Fraction(math.floor(bound) + 1), False)
            else:
                self._raise_lo(bound, rel is Rel.GT)
        else:
            if rel is Rel.GT and integer:
                self._lower_hi(Fraction(math.ceil(bound) - 1), False)
            else:
                self._lower_hi(bound, rel is Rel.GT)

    def _raise_lo(self, val: Fraction, strict: bool) -> None:
        if val > self.lo:
            self.lo, self.lo_strict = val, strict
        elif val == self.lo and strict:
            self.lo_strict = True

    def _lower_hi(self, val: Fraction, strict: bool) -> None:
        if val < self.hi:
            self.hi, self.hi_strict = val, strict
        elif val == self.hi and strict:
            self.hi_strict = True

    def empty(self) -> bool:
        return (self.lo > self.hi
                or (self.lo == self.hi and (self.lo_strict or self.hi_strict)))


class CompiledSystem:
    """A constraint system specialized against one machine+data binding.

    Classification of each atom after folding the binding in:

    * **constant** — decided here; a false one marks the system infeasible;
    * **row atom** — residual vars are all integer-domain (program params):
      a vectorized sign test per enumerated row;
    * **measure atom** — linear in exactly one ``P_*`` measure variable with
      row-only coefficients: contributes to an interval-emptiness test that
      replicates ``_propagate_bounds``;
    * anything else sets ``fallback`` and the caller must use the exact path.

    ``int_bounds`` holds the integer lower/upper bounds implied by
    univariate-linear row atoms — callers may prune enumeration domains with
    them (rows outside the bounds provably fail the corresponding atom).
    """

    __slots__ = ("binding", "infeasible", "fallback", "row_vars", "row_atoms",
                 "measure_atoms", "int_bounds")

    def __init__(self, system: ConstraintSystem, binding: Mapping[str, int]):
        self.binding = dict(binding)
        self.infeasible = False
        self.fallback = False
        self.row_vars: frozenset = frozenset()
        self.row_atoms: List[_RowAtom] = []
        self.measure_atoms: Dict[str, List[_MeasureAtom]] = {}
        row_vars = set()
        for atom in system.atoms:
            p = atom.poly.subs(binding)
            pvars = p.variables()
            if not pvars:
                if not _const_holds(p.constant_value(), atom.rel):
                    self.infeasible = True
                continue
            measures = {v for v in pvars if not is_integer_var(v)}
            if not measures:
                self.row_atoms.append(_RowAtom(p.compile(), atom.rel))
                row_vars |= pvars
                continue
            if len(measures) != 1:
                self.fallback = True
                continue
            (m,) = measures
            if p.degree(m) != 1:
                self.fallback = True
                continue
            k_terms: Dict[Monomial, Fraction] = {}
            c_terms: Dict[Monomial, Fraction] = {}
            for mono, coeff in p.terms.items():
                rest = tuple((v, e) for v, e in mono if v != m)
                if len(rest) == len(mono):
                    c_terms[mono] = coeff
                else:
                    k_terms[rest] = coeff
            k_poly, c_poly = Poly(k_terms), Poly(c_terms)
            k_cp, c_cp = compile_pair(k_poly, c_poly)
            self.measure_atoms.setdefault(m, []).append(
                _MeasureAtom(m, k_cp, c_cp, atom.rel))
            row_vars |= k_poly.variables() | c_poly.variables()
        self.row_vars = frozenset(row_vars)
        self._settle_constant_measures()
        self.int_bounds = self._integer_bounds()

    # -- specialize-time decisions -------------------------------------------
    def _settle_constant_measures(self) -> None:
        """Decide measure vars whose atoms are all binding-constant."""
        for m in list(self.measure_atoms):
            atoms = self.measure_atoms[m]
            if not all(a.k.poly.is_constant() and a.c.poly.is_constant()
                       for a in atoms):
                continue
            iv = _Interval()
            for a in atoms:
                k = a.k.poly.terms.get((), Fraction(0))
                c = a.c.poly.terms.get((), Fraction(0))
                if k == 0:
                    if not _const_holds(c, a.rel):
                        self.infeasible = True
                else:
                    iv.add(k, c, a.rel, is_integer_var(m))
            if iv.empty():
                self.infeasible = True
            del self.measure_atoms[m]     # same verdict for every row

    def _integer_bounds(self) -> Dict[str, Tuple[Optional[int], Optional[int]]]:
        out: Dict[str, Tuple[Optional[int], Optional[int]]] = {}
        for ra in self.row_atoms:
            poly = ra.cpoly.poly
            vs = poly.variables()
            if len(vs) != 1 or ra.rel is Rel.EQ:
                continue
            (var,) = vs
            if poly.degree(var) != 1:
                continue
            k = poly.coefficient(((var, 1),))
            c = poly.coefficient(())
            if k == 0:
                continue
            bound = -c / k
            lo, hi = out.get(var, (None, None))
            strict = ra.rel is Rel.GT
            if k > 0:
                b = math.floor(bound) + 1 if strict else math.ceil(bound)
                lo = b if lo is None else max(lo, b)
            else:
                b = math.ceil(bound) - 1 if strict else math.floor(bound)
                hi = b if hi is None else min(hi, b)
            out[var] = (lo, hi)
        return out

    def filter_domain(self, var: str, values: Sequence[int]) -> Tuple[int, ...]:
        """Prune candidate values outside the precomputed integer bounds."""
        lo, hi = self.int_bounds.get(var, (None, None))
        if lo is None and hi is None:
            return tuple(values)
        return tuple(v for v in values
                     if (lo is None or v >= lo) and (hi is None or v <= hi))

    # -- batched feasibility -------------------------------------------------
    def feasible_rows(self, cols: Mapping[str, np.ndarray],
                      maxvals: Mapping[str, int], n_rows: int) -> np.ndarray:
        """Boolean mask: which rows are *not provably inconsistent*.

        Exactly the inconsistency proofs of ``ConstraintSystem.check`` on the
        fully-bound residual system: constant-atom refutation plus interval
        emptiness over each measure variable.  Rows whose arithmetic cannot
        be certified exact in float64 are re-decided with exact Fractions.
        """
        ok = np.ones(n_rows, dtype=bool)
        if self.infeasible:
            ok[:] = False
            return ok
        exact_rows = np.zeros(n_rows, dtype=bool)   # rows needing fallback

        for ra in self.row_atoms:
            if ra.cpoly.max_abs_scaled(maxvals) < _EXACT_LIMIT:
                vals = ra.cpoly.eval_batch_scaled(cols)
                ok &= _rel_mask(vals, ra.rel)
            else:
                exact_rows |= ok                    # decide those rows exactly

        for m, atoms in self.measure_atoms.items():
            bounds = [max(a.k.max_abs_scaled(maxvals),
                          a.c.max_abs_scaled(maxvals)) for a in atoms]
            pair_limit = max(bounds + [_DEFAULT_HI])
            if pair_limit * pair_limit >= _EXACT_LIMIT:
                exact_rows |= ok
                continue
            ok &= self._measure_mask(atoms, cols, n_rows)

        if exact_rows.any():
            for r in np.flatnonzero(exact_rows & ok):
                asg = {v: int(cols[v][r]) for v in cols}
                if self._row_infeasible_exact(asg):
                    ok[r] = False
        return ok

    def _measure_mask(self, atoms: Sequence[_MeasureAtom],
                      cols: Mapping[str, np.ndarray],
                      n_rows: int) -> np.ndarray:
        """Vectorized interval-emptiness over one measure variable.

        Maintains per-row running bounds as exact rationals ``num/den``
        (den > 0) in certified-exact float64, mirroring ``_propagate_bounds``
        with Box defaults lo=0, hi=_DEFAULT_HI.  Measure variables are
        rationals, so strictness is tracked exactly instead of tightened to
        integers."""
        ok = np.ones(n_rows, dtype=bool)
        lo_num = np.zeros(n_rows)
        lo_den = np.ones(n_rows)
        lo_strict = np.zeros(n_rows, dtype=bool)
        hi_num = np.full(n_rows, float(_DEFAULT_HI))
        hi_den = np.ones(n_rows)
        hi_strict = np.zeros(n_rows, dtype=bool)

        def raise_lo(sel, num, den, strict):
            # new bound num/den > current lo_num/lo_den  (dens positive)
            gt = sel & (num * lo_den > lo_num * den)
            eq = sel & (num * lo_den == lo_num * den)
            lo_num[gt] = num[gt]
            lo_den[gt] = den[gt]
            lo_strict[gt] = strict
            if strict:
                lo_strict[eq] = True

        def lower_hi(sel, num, den, strict):
            lt = sel & (num * hi_den < hi_num * den)
            eq = sel & (num * hi_den == hi_num * den)
            hi_num[lt] = num[lt]
            hi_den[lt] = den[lt]
            hi_strict[lt] = strict
            if strict:
                hi_strict[eq] = True

        for a in atoms:
            K = a.k.eval_batch_scaled(cols)
            C = a.c.eval_batch_scaled(cols)
            K = np.broadcast_to(K, (n_rows,)).copy() if K.ndim == 0 else K
            C = np.broadcast_to(C, (n_rows,)).copy() if C.ndim == 0 else C
            zero = K == 0
            if zero.any():                     # atom degenerates to const
                ok &= ~zero | _rel_mask(C, a.rel)
            pos, neg = K > 0, K < 0
            strict = a.rel is Rel.GT
            if a.rel is Rel.EQ:
                # m == -C/K: tighten both sides, non-strict
                raise_lo(pos, -C, K, False)
                lower_hi(pos, -C, K, False)
                raise_lo(neg, C, -K, False)
                lower_hi(neg, C, -K, False)
            else:
                raise_lo(pos, -C, K, strict)   # bound = -C/K, den = K > 0
                lower_hi(neg, C, -K, strict)   # bound = -C/K = C/-K, den > 0
        empty = (lo_num * hi_den > hi_num * lo_den) | (
            (lo_num * hi_den == hi_num * lo_den) & (lo_strict | hi_strict))
        return ok & ~empty

    def _row_infeasible_exact(self, asg: Mapping[str, int]) -> bool:
        """Exact-Fraction fallback decision for one row (rare)."""
        intervals: Dict[str, _Interval] = {}
        for ra in self.row_atoms:
            if not _const_holds(ra.cpoly.eval_exact(asg), ra.rel):
                return True
        for m, atoms in self.measure_atoms.items():
            iv = intervals.setdefault(m, _Interval())
            for a in atoms:
                k = a.k.eval_exact(asg)
                c = a.c.eval_exact(asg)
                if k == 0:
                    if not _const_holds(c, a.rel):
                        return True
                else:
                    iv.add(k, c, a.rel, is_integer_var(m))
            if iv.empty():
                return True
        return False

    @property
    def decided(self) -> bool:
        """True when specialization alone settles feasibility (no residual
        row variables and every atom classified)."""
        return not self.fallback and not self.row_vars

    def __repr__(self) -> str:
        return (f"CompiledSystem(row_atoms={len(self.row_atoms)}, "
                f"measure_vars={sorted(self.measure_atoms)}, "
                f"infeasible={self.infeasible}, fallback={self.fallback})")


# ---------------------------------------------------------------------------
# Specialize-once cache: (system identity, binding) -> CompiledSystem
# ---------------------------------------------------------------------------
_SPEC_CACHE: "OrderedDict[tuple, Tuple[ConstraintSystem, CompiledSystem]]" = \
    OrderedDict()
_SPEC_CACHE_MAX = 4096
_SPEC_LOCK = threading.Lock()


def specialize_system(system: ConstraintSystem,
                      binding: Mapping[str, int]) -> CompiledSystem:
    """Memoized :class:`CompiledSystem` construction.

    Keyed on the system's identity + atom count (systems only ever grow by
    appending) and the exact binding; the cache keeps a strong reference to
    the system so identity keys stay valid while cached."""
    key = (id(system), len(system.atoms),
           tuple(sorted((k, int(v)) for k, v in binding.items())))
    with _SPEC_LOCK:
        hit = _SPEC_CACHE.get(key)
        if hit is not None:
            _SPEC_CACHE.move_to_end(key)
            return hit[1]
    cs = CompiledSystem(system, binding)
    with _SPEC_LOCK:
        _SPEC_CACHE[key] = (system, cs)
        while len(_SPEC_CACHE) > _SPEC_CACHE_MAX:
            _SPEC_CACHE.popitem(last=False)
    return cs


def clear_specialize_cache() -> None:
    with _SPEC_LOCK:
        _SPEC_CACHE.clear()
