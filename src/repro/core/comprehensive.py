"""Comprehensive optimization — Algorithms 1 and 2 of the paper.

``comprehensive_optimization`` is Algorithm 1 (top level recursion over
quintuples); ``optimize`` is Algorithm 2 (evaluate the next counter, fork
accept / refuse branches, prune inconsistent constraint systems).

The output is the paper's comprehensive optimization of Definition 2: a
sequence of :class:`~repro.core.plan.Leaf` pairs ``(C_i, S_i)`` satisfying

  (i)   constraint soundness — every kept system is consistent (or not
        provably inconsistent; see DESIGN.md §5 on the sound direction),
  (ii)  code soundness       — strategies are semantics-preserving,
  (iii) coverage             — accept/refuse add complementary constraints,
  (iv)  optimality           — along any path that exhausts σ(c), the final
        plan is a fix-point of every strategy in σ(c).

Tree shape properties proven in the paper (Lemmas 1-3) are enforced
structurally here and re-checked by tests/test_comprehensive.py.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .constraints import Constraint, ConstraintSystem, Verdict
from .counters import Counter, CounterKind
from .plan import FamilySpec, KernelPlan, Leaf, Quintuple
from .polynomial import Poly
from .strategies import Strategy


def initial_quintuple(family: FamilySpec,
                      domain_axioms: Sequence[Constraint] = ()) -> Quintuple:
    """Paper §3.6: λ empty, ω = O_1..O_w, γ = r_1..r_s,p_1..p_t, C = axioms."""
    counters = list(family.counters())
    strategies = list(family.strategies())
    C = ConstraintSystem()
    seen_limits = set()
    for c in counters:
        if c.limit_symbol in seen_limits:
            continue
        seen_limits.add(c.limit_symbol)
        if c.kind is CounterKind.PERFORMANCE:
            C.add(Constraint.ge(Poly.var(c.limit_symbol)))          # P_i >= 0
            C.add(Constraint.le(Poly.var(c.limit_symbol), 1))       # P_i <= 1
        else:
            C.add(Constraint.ge(Poly.var(c.limit_symbol)))          # R_i >= 0
    for ax in domain_axioms:
        C.add(ax)
    return Quintuple(
        plan=family.initial_plan(),
        lam=[],
        omega=[s.name for s in strategies],
        gamma=[c.name for c in counters],
        C=C,
    )


def _lookup(names: Sequence[str], table: Dict[str, object]) -> List[object]:
    return [table[n] for n in names]


def optimize(q: Quintuple, family: FamilySpec) -> List[Quintuple]:
    """Algorithm 2.  Returns the stack of child quintuples."""
    counters = {c.name: c for c in family.counters()}
    strategies = {s.name: s for s in family.strategies()}
    result: List[Quintuple] = []

    counter: Counter = counters[q.gamma[0]]
    q.gamma = q.gamma[1:]                # pop c from γ
    original = q.deepcopy()              # Line (5): fork material (post-pop)

    num, den = counter.evaluate(family, q.plan)
    limit = Poly.var(counter.limit_symbol)

    # ---- accept branch:  0 <= v <= Limit   (v = num/den, den > 0) ----------
    accept = q
    accept.C.add(Constraint.ge(num))                       # v >= 0
    accept.C.add(Constraint.ge(limit * den - num))         # v <= R_i / P_i
    result.append(accept)

    # ---- refuse branch: Limit < v, apply a strategy, re-evaluate c ---------
    applicable: Optional[Tuple[str, KernelPlan]] = None
    for s_name in original.omega:
        if s_name not in counter.sigma:
            continue
        transformed = strategies[s_name](original.plan)
        if transformed is not None:
            applicable = (s_name, transformed)
            break

    if applicable is not None:
        s_name, transformed = applicable
        refuse = original                                   # the deep copy
        refuse.C.add(Constraint.gt(num - limit * den))      # v > R_i / P_i
        if counter.kind is CounterKind.PERFORMANCE:
            refuse.C.add(Constraint.ge(den - num))          # v <= 1
        refuse.plan = transformed
        refuse.lam = refuse.lam + [s_name]
        refuse.omega = [n for n in refuse.omega if n != s_name]
        # push c back onto γ so the improved plan is re-measured
        refuse.gamma = [counter.name] + refuse.gamma
        result.append(refuse)

    # ---- prune inconsistent systems (paper R6 / RealTriangularize) ---------
    return [child for child in result if child.C.is_consistent()]


def comprehensive_optimization(family: FamilySpec,
                               domain_axioms: Sequence[Constraint] = (),
                               _q: Quintuple | None = None) -> List[Leaf]:
    """Algorithm 1.  Recursively process quintuples until γ is empty."""
    q = _q if _q is not None else initial_quintuple(family, domain_axioms)
    if q.processed():
        return [Leaf(constraints=q.C, plan=q.plan, applied=tuple(q.lam))]
    leaves: List[Leaf] = []
    for child in optimize(q, family):
        leaves.extend(
            comprehensive_optimization(family, domain_axioms, _q=child))
    return leaves


# ----------------------------------------------------------------------------
# Cached per-family trees: building the tree is an offline, machine-free step
# (the whole point of the paper); every runtime caller reuses it.  Leaf
# identity matters downstream — the compiled-system cache in
# repro.core.compiled keys on constraint-system identity, so serving the
# same list object keeps specializations shared across calls.
# ----------------------------------------------------------------------------
_TREE_CACHE: Dict[str, List[Leaf]] = {}
_TREE_LOCK = threading.Lock()


def comprehensive_tree(family: FamilySpec,
                       domain_axioms: Sequence[Constraint] = ()) -> List[Leaf]:
    key = family.name + "::" + ";".join(map(repr, domain_axioms))
    with _TREE_LOCK:
        hit = _TREE_CACHE.get(key)
    if hit is None:
        hit = comprehensive_optimization(family, domain_axioms)
        with _TREE_LOCK:
            hit = _TREE_CACHE.setdefault(key, hit)
    return hit


def clear_tree_cache() -> None:
    """Drop memoized trees (tests / families redefined at runtime)."""
    with _TREE_LOCK:
        _TREE_CACHE.clear()


def tree_report(leaves: Sequence[Leaf]) -> str:
    """Human-readable case discussion (paper Fig. 2 / Fig. 7 / Fig. 8)."""
    out = []
    for i, leaf in enumerate(leaves, 1):
        out.append(f"case {i}: {leaf.plan.describe()}")
        out.append(f"  applied: {', '.join(leaf.applied) or '(none)'}")
        for atom in leaf.constraints.atoms:
            out.append(f"  s.t. {atom}")
    return "\n".join(out)
