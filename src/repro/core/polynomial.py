"""Exact multivariate polynomials over Q.

This is the computer-algebra substrate the paper delegates to Maple's
RegularChains.  We only need the fragment used by comprehensive optimization
(paper §3.5-§3.7): polynomial arithmetic with exact rational coefficients,
substitution (full and partial), and enough structure for the constraint
solver in :mod:`repro.core.constraints`.

Representation: ``{monomial: Fraction}`` where a monomial is a sorted tuple of
``(variable_name, exponent)`` pairs with positive exponents.  The empty tuple
is the constant monomial.

Monomials are interned process-wide: equal monomials share one tuple object,
so the dict operations that dominate polynomial arithmetic hit the identity
fast path, and the memoized monomial product below stays small.
"""
from __future__ import annotations

import functools
import itertools
from fractions import Fraction
from typing import Dict, Iterable, Mapping, Tuple, Union

Monomial = Tuple[Tuple[str, int], ...]
Scalar = Union[int, float, Fraction]
PolyLike = Union["Poly", Scalar]

_ZERO = Fraction(0)

_MONO_INTERN: Dict[Monomial, Monomial] = {(): ()}


def _intern_mono(m: Monomial) -> Monomial:
    return _MONO_INTERN.setdefault(m, m)


def _as_fraction(x: Scalar) -> Fraction:
    if isinstance(x, Fraction):
        return x
    if isinstance(x, int):
        return Fraction(x)
    if isinstance(x, float):
        return Fraction(x).limit_denominator(10**12)
    raise TypeError(f"cannot coerce {type(x)} to Fraction")


@functools.lru_cache(maxsize=1 << 16)
def _mono_mul(a: Monomial, b: Monomial) -> Monomial:
    exps: Dict[str, int] = {}
    for var, e in itertools.chain(a, b):
        exps[var] = exps.get(var, 0) + e
    return _intern_mono(tuple(sorted((v, e) for v, e in exps.items() if e)))


class Poly:
    """Immutable exact multivariate polynomial."""

    __slots__ = ("terms", "_compiled")

    def __init__(self, terms: Mapping[Monomial, Fraction] | None = None):
        clean: Dict[Monomial, Fraction] = {}
        if terms:
            for mono, coeff in terms.items():
                c = _as_fraction(coeff)
                if c != 0:
                    clean[_intern_mono(mono)] = c
        object.__setattr__(self, "terms", clean)
        object.__setattr__(self, "_compiled", None)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def const(c: Scalar) -> "Poly":
        c = _as_fraction(c)
        return Poly({(): c} if c != 0 else {})

    @staticmethod
    def var(name: str, exp: int = 1) -> "Poly":
        if exp < 0:
            raise ValueError("negative exponents are not polynomials")
        if exp == 0:
            return Poly.const(1)
        return Poly({((name, exp),): Fraction(1)})

    @staticmethod
    def coerce(x: PolyLike) -> "Poly":
        return x if isinstance(x, Poly) else Poly.const(x)

    # -- structure ---------------------------------------------------------
    def variables(self) -> frozenset:
        return frozenset(v for mono in self.terms for v, _ in mono)

    def degree(self, var: str | None = None) -> int:
        if not self.terms:
            return 0
        if var is None:
            return max(sum(e for _, e in mono) for mono in self.terms)
        return max((e for mono in self.terms for v, e in mono if v == var), default=0)

    def is_constant(self) -> bool:
        return all(mono == () for mono in self.terms)

    def constant_value(self) -> Fraction:
        if not self.is_constant():
            raise ValueError(f"{self} is not constant")
        return self.terms.get((), _ZERO)

    def coefficient(self, mono: Monomial) -> Fraction:
        return self.terms.get(tuple(sorted(mono)), _ZERO)

    # -- arithmetic ---------------------------------------------------------
    def __add__(self, other: PolyLike) -> "Poly":
        other = Poly.coerce(other)
        out = dict(self.terms)
        for mono, c in other.terms.items():
            out[mono] = out.get(mono, _ZERO) + c
        return Poly(out)

    __radd__ = __add__

    def __neg__(self) -> "Poly":
        return Poly({m: -c for m, c in self.terms.items()})

    def __sub__(self, other: PolyLike) -> "Poly":
        return self + (-Poly.coerce(other))

    def __rsub__(self, other: PolyLike) -> "Poly":
        return Poly.coerce(other) + (-self)

    def __mul__(self, other: PolyLike) -> "Poly":
        other = Poly.coerce(other)
        out: Dict[Monomial, Fraction] = {}
        for m1, c1 in self.terms.items():
            for m2, c2 in other.terms.items():
                m = _mono_mul(m1, m2)
                out[m] = out.get(m, _ZERO) + c1 * c2
        return Poly(out)

    __rmul__ = __mul__

    def __pow__(self, n: int) -> "Poly":
        if n < 0:
            raise ValueError("negative power")
        result = Poly.const(1)
        base = self
        while n:
            if n & 1:
                result = result * base
            base = base * base
            n >>= 1
        return result

    def __truediv__(self, other: Scalar) -> "Poly":
        c = _as_fraction(other)
        return Poly({m: v / c for m, v in self.terms.items()})

    # -- evaluation ---------------------------------------------------------
    def subs(self, assignment: Mapping[str, Union[Scalar, "Poly"]]) -> "Poly":
        """Partial or full substitution; values may themselves be Polys."""
        if all(not isinstance(v, Poly) for v in assignment.values()):
            # Numeric-only fast path: fold bound variables straight into the
            # coefficient dict without building intermediate Polys.
            out: Dict[Monomial, Fraction] = {}
            for mono, coeff in self.terms.items():
                c = coeff
                residual = mono
                if any(var in assignment for var, _ in mono):
                    free = []
                    for var, exp in mono:
                        if var in assignment:
                            c *= _as_fraction(assignment[var]) ** exp
                        else:
                            free.append((var, exp))
                    residual = _intern_mono(tuple(free))
                prev = out.get(residual)
                out[residual] = c if prev is None else prev + c
            return Poly(out)
        acc = Poly.const(0)
        for mono, coeff in self.terms.items():
            term = Poly.const(coeff)
            for var, exp in mono:
                if var in assignment:
                    term = term * (Poly.coerce(assignment[var]) ** exp)
                else:
                    term = term * Poly.var(var, exp)
            acc = acc + term
        return acc

    def eval(self, assignment: Mapping[str, Scalar]) -> Fraction:
        """Full numeric evaluation; raises if a variable is missing."""
        total = _ZERO
        for mono, coeff in self.terms.items():
            val = coeff
            for var, exp in mono:
                if var not in assignment:
                    raise KeyError(f"unbound variable {var!r} in {self}")
                val *= _as_fraction(assignment[var]) ** exp
            total += val
        return total

    def eval_float(self, assignment: Mapping[str, float]) -> float:
        """Fast approximate evaluation (witness screening only)."""
        total = 0.0
        for mono, coeff in self.terms.items():
            val = float(coeff)
            for var, exp in mono:
                val *= float(assignment[var]) ** exp
            total += val
        return total

    def compile(self) -> "CompiledPoly":
        """Lower to a flat coefficient/exponent array program (cached).

        The returned :class:`repro.core.compiled.CompiledPoly` evaluates whole
        batches of assignments with NumPy and keeps this Poly around for the
        exact-Fraction single-point fallback."""
        cp = self._compiled
        if cp is None:
            from .compiled import CompiledPoly
            cp = CompiledPoly(self)
            object.__setattr__(self, "_compiled", cp)
        return cp

    # -- comparisons / hashing ----------------------------------------------
    def __eq__(self, other) -> bool:
        if isinstance(other, (int, float, Fraction)):
            other = Poly.const(other)
        if not isinstance(other, Poly):
            return NotImplemented
        return self.terms == other.terms

    def __hash__(self) -> int:
        return hash(frozenset(self.terms.items()))

    def __bool__(self) -> bool:
        return bool(self.terms)

    # -- pretty -------------------------------------------------------------
    def __repr__(self) -> str:
        if not self.terms:
            return "0"
        parts = []
        for mono in sorted(self.terms, key=lambda m: (-sum(e for _, e in m), m)):
            c = self.terms[mono]
            factors = "*".join(
                f"{v}^{e}" if e > 1 else v for v, e in mono
            )
            if not factors:
                parts.append(str(c))
            elif c == 1:
                parts.append(factors)
            elif c == -1:
                parts.append(f"-{factors}")
            else:
                parts.append(f"{c}*{factors}")
        s = " + ".join(parts).replace("+ -", "- ")
        return s


def V(name: str) -> Poly:
    """Shorthand variable constructor."""
    return Poly.var(name)
