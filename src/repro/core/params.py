"""Machine / program / data parameters (paper §3.1-§3.2).

The paper's machine parameters are hardware resource limits ``R_1..R_s`` and
performance measures ``P_1..P_t``; program/data parameters come from the code
fragment.  All stay *symbolic* through comprehensive optimization and are only
bound when the generated artifact is loaded on a concrete machine.

TPU adaptation (DESIGN.md §2): the binding resources on TPU are VMEM bytes per
core and tile alignment, not registers/threads.  We keep a VREG-pressure
counter as the moral equivalent of the paper's register estimate.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Mapping, Tuple


class ParamKind(enum.Enum):
    MACHINE_RESOURCE = "machine_resource"     # R_i — hardware resource limit
    MACHINE_PERFORMANCE = "machine_perf"      # P_i — performance measure in [0,1]
    PROGRAM = "program"                       # E_i — e.g. block sizes, grain
    DATA = "data"                             # D_i — e.g. matrix order, seq len


@dataclass(frozen=True)
class ParamSymbol:
    name: str
    kind: ParamKind
    doc: str = ""


# --- canonical TPU machine-parameter symbols --------------------------------
VMEM = ParamSymbol("V", ParamKind.MACHINE_RESOURCE,
                   "VMEM bytes available per TensorCore")
VREGS = ParamSymbol("G", ParamKind.MACHINE_RESOURCE,
                    "vector-register budget (lane-values) per core")
CORES = ParamSymbol("CORES", ParamKind.MACHINE_RESOURCE,
                    "number of TensorCores in the slice")
SUBLANE = ParamSymbol("SUBLANE", ParamKind.MACHINE_RESOURCE,
                      "second-minor tile dim (8 for f32, 16 bf16, 32 int8)")
LANE = ParamSymbol("LANE", ParamKind.MACHINE_RESOURCE,
                   "minor tile dim (128)")
MXU = ParamSymbol("MXU", ParamKind.MACHINE_RESOURCE,
                  "systolic array dimension (128)")

OCCUPANCY = ParamSymbol("P_occ", ParamKind.MACHINE_PERFORMANCE,
                        "achievable grid-occupancy ratio")
MXU_UTIL = ParamSymbol("P_mxu", ParamKind.MACHINE_PERFORMANCE,
                       "achievable MXU tile-utilization ratio")

RESOURCE_SYMBOLS = (VMEM, VREGS, CORES, SUBLANE, LANE, MXU)
PERFORMANCE_SYMBOLS = (OCCUPANCY, MXU_UTIL)


@dataclass(frozen=True)
class MachineDescription:
    """Concrete values bound at load time (paper: 'looked up when the
    generated code is loaded on the target machine')."""

    name: str
    vmem_bytes: int
    vreg_budget: int              # lane-values; 2 * 512 VREGs * (8*128) is gen-dep
    num_cores: int
    sublane: int
    lane: int
    mxu: int
    hbm_bytes: int
    hbm_bw: float                 # bytes/s
    peak_flops_bf16: float        # FLOP/s per core-pair (chip)
    ici_bw: float                 # bytes/s per link per chip
    ici_links: int = 4            # v5e 2D torus: 4 links/chip

    def bindings(self) -> Dict[str, int]:
        """Values for the machine symbols used in constraint systems."""
        return {
            VMEM.name: self.vmem_bytes,
            VREGS.name: self.vreg_budget,
            CORES.name: self.num_cores,
            SUBLANE.name: self.sublane,
            LANE.name: self.lane,
            MXU.name: self.mxu,
        }


# TPU v5e (the dry-run / roofline target; constants from the task spec).
TPU_V5E = MachineDescription(
    name="tpu_v5e",
    vmem_bytes=128 * 1024 * 1024,     # ~128 MiB VMEM per core
    vreg_budget=4096,                  # usable f32 lane-rows before spill (est.)
    num_cores=1,                       # per-chip kernels see one TensorCore
    sublane=8,
    lane=128,
    mxu=128,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    peak_flops_bf16=197e12,
    ici_bw=50e9,
    ici_links=4,
)

# A Fermi-class description used only to replay the paper's own case studies
# (Tesla M2050 figures: R registers/thread, T threads/block, Z_B shared words).
PAPER_M2050 = MachineDescription(
    name="paper_m2050",
    vmem_bytes=48 * 1024,              # 48 KiB shared memory / block ~ Z_B
    vreg_budget=63,                    # max registers per thread ~ R
    num_cores=14,                      # SMs
    sublane=1,
    lane=32,                           # warp size
    mxu=1,
    hbm_bytes=3 * 1024**3,
    hbm_bw=148e9,
    peak_flops_bf16=1.03e12,
    ici_bw=8e9,
    ici_links=1,
)

MACHINES: Mapping[str, MachineDescription] = {
    m.name: m for m in (TPU_V5E, PAPER_M2050)
}
