"""Semi-algebraic constraint systems (RealTriangularize stand-in).

The paper (§3.5, R4-R6) manipulates conjunctions of polynomial equations and
inequalities over the machine / program / data parameters and prunes branches
whose systems are inconsistent, using the RegularChains library in Maple.

We implement the fragment comprehensive optimization actually needs, under the
paper's hypothesis (H1): all parameters range over the non-negative integers
(performance measures over [0,1] rationals, handled by scaling).

Consistency decision procedure (sound pruning, over-approximating keep):

1. *Normalization*  — every atom is ``p REL 0`` with REL in {>=, >, ==}.
2. *Syntactic contradiction* — identical polynomials with incompatible
   numeric windows (``p >= a`` and ``-p >= -b`` with a > b, etc.).
3. *Bound propagation* — atoms univariate-linear in one variable tighten an
   interval box; an empty box proves inconsistency.
4. *Witness search*   — seeded deterministic search over the box lattice
   (powers of two, bound endpoints, small offsets, then pseudo-random
   integers).  A witness proves consistency.

If neither emptiness nor a witness is established we report ``UNKNOWN`` and
the caller keeps the branch: this preserves the paper's coverage property
(Def. 2 (iii)) — we may retain a dead leaf but never drop a live one.
"""
from __future__ import annotations

import enum
import math
import random
from dataclasses import dataclass, field
from fractions import Fraction
from typing import (TYPE_CHECKING, Dict, Iterable, List, Mapping, Optional,
                    Tuple)

import numpy as np

from .polynomial import Poly, PolyLike, Scalar

if TYPE_CHECKING:  # pragma: no cover
    from .compiled import CompiledSystem

# Domain convention (paper hypothesis H1): every parameter ranges over the
# non-negative integers EXCEPT the performance measures P_i, which are
# rationals in [0, 1].  Performance-measure symbols are named with this
# prefix throughout the repo (see core.params PERFORMANCE_SYMBOLS).
PERF_MEASURE_PREFIX = "P_"


def is_integer_var(name: str) -> bool:
    """True for variables that range over integers under hypothesis H1."""
    return not name.startswith(PERF_MEASURE_PREFIX)


class Rel(enum.Enum):
    GE = ">="   # p >= 0
    GT = ">"    # p > 0
    EQ = "=="   # p == 0


@dataclass(frozen=True)
class Constraint:
    """A single polynomial atom ``poly REL 0``."""

    poly: Poly
    rel: Rel

    # -- constructors --------------------------------------------------------
    @staticmethod
    def ge(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.GE)

    @staticmethod
    def gt(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.GT)

    @staticmethod
    def le(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(rhs) - Poly.coerce(lhs), Rel.GE)

    @staticmethod
    def lt(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(rhs) - Poly.coerce(lhs), Rel.GT)

    @staticmethod
    def eq(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.EQ)

    # -- semantics -----------------------------------------------------------
    def holds(self, assignment: Mapping[str, Scalar]) -> bool:
        v = self.poly.eval(assignment)
        if self.rel is Rel.GE:
            return v >= 0
        if self.rel is Rel.GT:
            return v > 0
        return v == 0

    def subs(self, assignment: Mapping[str, Scalar]) -> "Constraint":
        return Constraint(self.poly.subs(assignment), self.rel)

    def variables(self) -> frozenset:
        return self.poly.variables()

    def trivially_true(self) -> bool:
        if not self.poly.is_constant():
            return False
        c = self.poly.constant_value()
        return (c >= 0 if self.rel is Rel.GE else c > 0 if self.rel is Rel.GT
                else c == 0)

    def trivially_false(self) -> bool:
        return self.poly.is_constant() and not self.trivially_true()

    def __repr__(self) -> str:
        return f"{self.poly} {self.rel.value} 0"


class Verdict(enum.Enum):
    CONSISTENT = "consistent"        # witness found
    INCONSISTENT = "inconsistent"    # emptiness proven
    UNKNOWN = "unknown"              # keep the branch (over-approximation)


_DEFAULT_HI = 1 << 24  # search ceiling for unbounded integer parameters


def _log_uniform_int(rng: random.Random, lo: int, hi: int) -> int:
    """Log-uniform integer in [lo, hi] by rejection sampling.

    Exponents are drawn over [0, (hi - lo + 1).bit_length()] — inclusive of
    the top, so ``hi`` itself is reachable for every span — and out-of-box
    values are rejected; clamping them to ``hi`` instead (the old behaviour)
    silently piled up to half the probability mass on the upper endpoint."""
    if hi <= lo:
        return lo
    bits = (hi - lo + 1).bit_length()
    for _ in range(16):
        val = lo + int(2 ** (rng.random() * bits)) - 1
        if val <= hi:
            return val
    return rng.randint(lo, hi)


@dataclass
class Box:
    """Per-variable rational interval [lo, hi] with open-endpoint flags.

    Strict bounds on *rational* variables (the performance measures) are
    recorded exactly via the strictness flags; strict bounds on integer
    variables are tightened to the adjacent integer before they get here
    (see ``_propagate_bounds``), so they arrive closed."""

    lo: Dict[str, Fraction] = field(default_factory=dict)
    hi: Dict[str, Fraction] = field(default_factory=dict)
    lo_strict: Dict[str, bool] = field(default_factory=dict)
    hi_strict: Dict[str, bool] = field(default_factory=dict)

    def get(self, var: str) -> Tuple[Fraction, Fraction]:
        return (self.lo.get(var, Fraction(0)),
                self.hi.get(var, Fraction(_DEFAULT_HI)))

    def tighten_lo(self, var: str, val: Fraction,
                   strict: bool = False) -> None:
        cur = self.lo.get(var, Fraction(0))
        if val > cur:
            self.lo[var] = val
            self.lo_strict[var] = strict
        elif val == cur and strict:
            self.lo[var] = val
            self.lo_strict[var] = True

    def tighten_hi(self, var: str, val: Fraction,
                   strict: bool = False) -> None:
        cur = self.hi.get(var, Fraction(_DEFAULT_HI))
        if val < cur:
            self.hi[var] = val
            self.hi_strict[var] = strict
        elif val == cur and strict:
            self.hi[var] = val
            self.hi_strict[var] = True

    def empty(self) -> bool:
        for var in set(self.lo) | set(self.hi):
            lo, hi = self.get(var)
            if lo > hi:
                return True
            if lo == hi and (self.lo_strict.get(var, False)
                             or self.hi_strict.get(var, False)):
                return True
        return False


class ConstraintSystem:
    """Conjunction of :class:`Constraint` atoms with incremental ``add``.

    Mirrors the role of the paper's ``C(S)`` component of the quintuple
    (§3.6 item 4): it starts from the domain axioms (all parameters >= 0,
    performance measures in [0,1]) and grows by one inequality per
    accept/refuse edge.
    """

    def __init__(self, atoms: Iterable[Constraint] = ()):  # noqa: D401
        self.atoms: List[Constraint] = list(atoms)

    def copy(self) -> "ConstraintSystem":
        return ConstraintSystem(self.atoms)

    def add(self, atom: Constraint) -> "ConstraintSystem":
        self.atoms.append(atom)
        return self

    def variables(self) -> frozenset:
        out = frozenset()
        for a in self.atoms:
            out |= a.variables()
        return out

    def holds(self, assignment: Mapping[str, Scalar]) -> bool:
        return all(a.holds(assignment) for a in self.atoms)

    def subs(self, assignment: Mapping[str, Scalar]) -> "ConstraintSystem":
        return ConstraintSystem(a.subs(assignment) for a in self.atoms)

    def specialize(self, binding: Mapping[str, int]) -> "CompiledSystem":
        """Partial-evaluate machine+data symbols once; classify residual
        atoms and return a batched evaluator (memoized per binding).  See
        :mod:`repro.core.compiled`."""
        from .compiled import specialize_system
        return specialize_system(self, binding)

    # -- consistency ---------------------------------------------------------
    def _propagate_bounds(self) -> Optional[Box]:
        """Interval box from univariate-linear atoms.  None => inconsistent."""
        box = Box()
        for _ in range(4):  # a few rounds; atoms here are simple
            for a in self.atoms:
                if a.trivially_false():
                    return None
                vs = a.variables()
                if len(vs) != 1:
                    continue
                (var,) = vs
                if a.poly.degree(var) != 1:
                    continue
                # poly = k*var + c  REL 0
                k = a.poly.coefficient(((var, 1),))
                c = a.poly.coefficient(())
                if k == 0:
                    continue
                bound = -c / k
                strict = a.rel is Rel.GT
                if a.rel is Rel.EQ:
                    box.tighten_lo(var, bound)
                    box.tighten_hi(var, bound)
                elif k > 0:  # var >= bound (or >)
                    if strict and is_integer_var(var):
                        # integer domain: p > b  <=>  p >= floor(b) + 1
                        box.tighten_lo(var, Fraction(math.floor(bound) + 1))
                    else:
                        box.tighten_lo(var, bound, strict=strict)
                else:        # var <= bound (or <)
                    if strict and is_integer_var(var):
                        # integer domain: p < b  <=>  p <= ceil(b) - 1
                        box.tighten_hi(var, Fraction(math.ceil(bound) - 1))
                    else:
                        box.tighten_hi(var, bound, strict=strict)
            if box.empty():
                return None
        return box

    def _pairwise_contradiction(self) -> bool:
        """p >= a together with p <= b for the same p and a > b, etc."""
        windows: Dict[Poly, Tuple[Fraction, Fraction]] = {}
        for a in self.atoms:
            # split poly into (non-constant part, constant): part + c REL 0
            c = a.poly.coefficient(())
            part = a.poly - Poly.const(c)
            if not part:
                continue
            # canonicalize sign by the first sorted monomial's coefficient
            key_mono = sorted(part.terms)[0]
            sign = 1 if part.terms[key_mono] > 0 else -1
            if sign < 0:
                # atom is  -part_pos + c >= 0  <=>  part_pos <= c
                part = -part
                lo, hi = windows.get(part, (Fraction(-(1 << 62)), Fraction(1 << 62)))
                hi = min(hi, c)
                windows[part] = (lo, hi)
            else:
                # part_pos + c >= 0 => part_pos >= -c
                lo, hi = windows.get(part, (Fraction(-(1 << 62)), Fraction(1 << 62)))
                lo = max(lo, -c)
                windows[part] = (lo, hi)
        return any(lo > hi for lo, hi in windows.values())

    def check(self, *, seed: int = 0, samples: int = 4000) -> Verdict:
        if not self.atoms:
            self._last_witness = {}
            return Verdict.CONSISTENT
        if any(a.trivially_false() for a in self.atoms):
            return Verdict.INCONSISTENT
        if self._pairwise_contradiction():
            return Verdict.INCONSISTENT
        box = self._propagate_bounds()
        if box is None:
            return Verdict.INCONSISTENT
        variables = sorted(self.variables())
        if not variables:
            # every atom constant and none false: the empty assignment is
            # the witness (witness() reads _last_witness on CONSISTENT)
            self._last_witness = {}
            return Verdict.CONSISTENT

        # --- witness search over the integer lattice inside the box ---------
        def candidates(var: str) -> List[Fraction]:
            lo, hi = box.get(var)
            lo_i = int(lo) if lo == int(lo) else int(lo) + 1
            hi_i = int(hi)
            vals: List[Fraction] = []
            for v in [lo_i, lo_i + 1, hi_i, hi_i - 1, 0, 1, 2]:
                if lo <= v <= hi:
                    vals.append(Fraction(v))
            p = 1
            while p <= hi_i and len(vals) < 40:
                if lo <= p:
                    vals.append(Fraction(p))
                p <<= 1
            # rational midpoints help for [0,1] performance measures
            mid = (lo + hi) / 2
            if lo <= mid <= hi:
                vals.append(mid)
            seen, out = set(), []
            for v in vals:
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

        cand = {v: candidates(v) for v in variables}
        n_trials = min(samples, 600)
        if n_trials <= 0:
            return Verdict.UNKNOWN
        # Vectorized witness search: generate the whole trial lattice up
        # front (first 8 trials deterministic, the rest pseudo-random), run
        # the float screen over all trials at once with the compiled batch
        # evaluators, and exact-verify candidates in trial order.  Only the
        # first float-clean trial pays exact Fraction arithmetic.
        rs = np.random.RandomState(seed)
        det = min(8, n_trials)
        idx: Dict[str, np.ndarray] = {}
        fcols: Dict[str, np.ndarray] = {}
        for v in variables:
            vals = cand[v]
            k = len(vals)
            ix = np.concatenate([
                np.arange(det, dtype=np.int64) % k,
                rs.randint(0, k, size=n_trials - det),
            ])
            idx[v] = ix
            fcols[v] = np.array([float(x) for x in vals])[ix]
        ok = np.ones(n_trials, dtype=bool)
        for a in self.atoms:
            vals = np.broadcast_to(a.poly.compile().eval_batch(fcols),
                                   (n_trials,))
            if a.rel is Rel.GE:
                ok &= vals >= -1e-9
            elif a.rel is Rel.GT:
                ok &= vals > 1e-12
            else:
                ok &= np.abs(vals) <= 1e-9
        for t in np.flatnonzero(ok):
            asg = {v: cand[v][int(idx[v][t])] for v in variables}
            if self.holds(asg):
                self._last_witness = dict(asg)
                return Verdict.CONSISTENT
        return Verdict.UNKNOWN

    def is_consistent(self, **kw) -> bool:
        """Paper semantics: prune only on *proven* emptiness."""
        return self.check(**kw) is not Verdict.INCONSISTENT

    def witness(self, *, seed: int = 0, samples: int = 4000
                ) -> Optional[Dict[str, Fraction]]:
        """Return a satisfying assignment if the search finds one.

        First reuses the lattice-candidate search from :meth:`check` (bound
        endpoints + powers of two find small-product witnesses that uniform
        sampling over a 2^24 box essentially never hits), then falls back to
        log-uniform random sampling."""
        if not self.atoms:
            return {}
        if self.check(seed=seed) is Verdict.CONSISTENT:
            return dict(self._last_witness)
        variables = sorted(self.variables())
        box = self._propagate_bounds()
        if box is None:
            return None
        rng = random.Random(seed)
        for _ in range(samples):
            asg = {}
            for v in variables:
                lo, hi = box.get(v)
                lo_i, hi_i = int(lo), min(int(hi), _DEFAULT_HI)
                lo_i, hi_i = min(lo_i, hi_i), max(lo_i, hi_i)
                # log-uniform favours small values (paper domains are sizes)
                asg[v] = Fraction(_log_uniform_int(rng, lo_i, hi_i))
            if self.holds(asg):
                return asg
        return None

    def __repr__(self) -> str:
        return "{ " + " ;  ".join(map(repr, self.atoms)) + " }"

    def __len__(self) -> int:
        return len(self.atoms)

    # Value equality so artifact round-trips can assert leaf-for-leaf
    # identity.  Atom *order* is compared: conjunction semantics are
    # order-free, but serialization must preserve structure exactly.
    def __eq__(self, other) -> bool:
        if not isinstance(other, ConstraintSystem):
            return NotImplemented
        return self.atoms == other.atoms

    def __hash__(self) -> int:
        return hash(tuple(self.atoms))
