"""Semi-algebraic constraint systems (RealTriangularize stand-in).

The paper (§3.5, R4-R6) manipulates conjunctions of polynomial equations and
inequalities over the machine / program / data parameters and prunes branches
whose systems are inconsistent, using the RegularChains library in Maple.

We implement the fragment comprehensive optimization actually needs, under the
paper's hypothesis (H1): all parameters range over the non-negative integers
(performance measures over [0,1] rationals, handled by scaling).

Consistency decision procedure (sound pruning, over-approximating keep):

1. *Normalization*  — every atom is ``p REL 0`` with REL in {>=, >, ==}.
2. *Syntactic contradiction* — identical polynomials with incompatible
   numeric windows (``p >= a`` and ``-p >= -b`` with a > b, etc.).
3. *Bound propagation* — atoms univariate-linear in one variable tighten an
   interval box; an empty box proves inconsistency.
4. *Witness search*   — seeded deterministic search over the box lattice
   (powers of two, bound endpoints, small offsets, then pseudo-random
   integers).  A witness proves consistency.

If neither emptiness nor a witness is established we report ``UNKNOWN`` and
the caller keeps the branch: this preserves the paper's coverage property
(Def. 2 (iii)) — we may retain a dead leaf but never drop a live one.
"""
from __future__ import annotations

import enum
import itertools
import random
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .polynomial import Poly, PolyLike, Scalar


class Rel(enum.Enum):
    GE = ">="   # p >= 0
    GT = ">"    # p > 0
    EQ = "=="   # p == 0


@dataclass(frozen=True)
class Constraint:
    """A single polynomial atom ``poly REL 0``."""

    poly: Poly
    rel: Rel

    # -- constructors --------------------------------------------------------
    @staticmethod
    def ge(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.GE)

    @staticmethod
    def gt(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.GT)

    @staticmethod
    def le(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(rhs) - Poly.coerce(lhs), Rel.GE)

    @staticmethod
    def lt(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(rhs) - Poly.coerce(lhs), Rel.GT)

    @staticmethod
    def eq(lhs: PolyLike, rhs: PolyLike = 0) -> "Constraint":
        return Constraint(Poly.coerce(lhs) - Poly.coerce(rhs), Rel.EQ)

    # -- semantics -----------------------------------------------------------
    def holds(self, assignment: Mapping[str, Scalar]) -> bool:
        v = self.poly.eval(assignment)
        if self.rel is Rel.GE:
            return v >= 0
        if self.rel is Rel.GT:
            return v > 0
        return v == 0

    def subs(self, assignment: Mapping[str, Scalar]) -> "Constraint":
        return Constraint(self.poly.subs(assignment), self.rel)

    def variables(self) -> frozenset:
        return self.poly.variables()

    def trivially_true(self) -> bool:
        if not self.poly.is_constant():
            return False
        c = self.poly.constant_value()
        return (c >= 0 if self.rel is Rel.GE else c > 0 if self.rel is Rel.GT
                else c == 0)

    def trivially_false(self) -> bool:
        return self.poly.is_constant() and not self.trivially_true()

    def __repr__(self) -> str:
        return f"{self.poly} {self.rel.value} 0"


class Verdict(enum.Enum):
    CONSISTENT = "consistent"        # witness found
    INCONSISTENT = "inconsistent"    # emptiness proven
    UNKNOWN = "unknown"              # keep the branch (over-approximation)


_DEFAULT_HI = 1 << 24  # search ceiling for unbounded integer parameters


@dataclass
class Box:
    """Per-variable closed rational interval [lo, hi]."""

    lo: Dict[str, Fraction] = field(default_factory=dict)
    hi: Dict[str, Fraction] = field(default_factory=dict)

    def get(self, var: str) -> Tuple[Fraction, Fraction]:
        return (self.lo.get(var, Fraction(0)),
                self.hi.get(var, Fraction(_DEFAULT_HI)))

    def tighten_lo(self, var: str, val: Fraction) -> None:
        cur = self.lo.get(var, Fraction(0))
        if val > cur:
            self.lo[var] = val

    def tighten_hi(self, var: str, val: Fraction) -> None:
        cur = self.hi.get(var, Fraction(_DEFAULT_HI))
        if val < cur:
            self.hi[var] = val

    def empty(self) -> bool:
        for var in set(self.lo) | set(self.hi):
            lo, hi = self.get(var)
            if lo > hi:
                return True
        return False


class ConstraintSystem:
    """Conjunction of :class:`Constraint` atoms with incremental ``add``.

    Mirrors the role of the paper's ``C(S)`` component of the quintuple
    (§3.6 item 4): it starts from the domain axioms (all parameters >= 0,
    performance measures in [0,1]) and grows by one inequality per
    accept/refuse edge.
    """

    def __init__(self, atoms: Iterable[Constraint] = ()):  # noqa: D401
        self.atoms: List[Constraint] = list(atoms)

    def copy(self) -> "ConstraintSystem":
        return ConstraintSystem(self.atoms)

    def add(self, atom: Constraint) -> "ConstraintSystem":
        self.atoms.append(atom)
        return self

    def variables(self) -> frozenset:
        out = frozenset()
        for a in self.atoms:
            out |= a.variables()
        return out

    def holds(self, assignment: Mapping[str, Scalar]) -> bool:
        return all(a.holds(assignment) for a in self.atoms)

    def subs(self, assignment: Mapping[str, Scalar]) -> "ConstraintSystem":
        return ConstraintSystem(a.subs(assignment) for a in self.atoms)

    # -- consistency ---------------------------------------------------------
    def _propagate_bounds(self) -> Optional[Box]:
        """Interval box from univariate-linear atoms.  None => inconsistent."""
        box = Box()
        for _ in range(4):  # a few rounds; atoms here are simple
            for a in self.atoms:
                if a.trivially_false():
                    return None
                vs = a.variables()
                if len(vs) != 1:
                    continue
                (var,) = vs
                if a.poly.degree(var) != 1:
                    continue
                # poly = k*var + c  REL 0
                k = a.poly.coefficient(((var, 1),))
                c = a.poly.coefficient(())
                if k == 0:
                    continue
                bound = -c / k
                strict = a.rel is Rel.GT
                if a.rel is Rel.EQ:
                    box.tighten_lo(var, bound)
                    box.tighten_hi(var, bound)
                elif k > 0:  # var >= bound (or >)
                    box.tighten_lo(var, bound + (Fraction(1, 10**9) if strict else 0))
                else:        # var <= bound (or <)
                    box.tighten_hi(var, bound - (Fraction(1, 10**9) if strict else 0))
            if box.empty():
                return None
        return box

    def _pairwise_contradiction(self) -> bool:
        """p >= a together with p <= b for the same p and a > b, etc."""
        windows: Dict[Poly, Tuple[Fraction, Fraction]] = {}
        for a in self.atoms:
            # split poly into (non-constant part, constant): part + c REL 0
            c = a.poly.coefficient(())
            part = a.poly - Poly.const(c)
            if not part:
                continue
            # canonicalize sign by the first sorted monomial's coefficient
            key_mono = sorted(part.terms)[0]
            sign = 1 if part.terms[key_mono] > 0 else -1
            if sign < 0:
                # atom is  -part_pos + c >= 0  <=>  part_pos <= c
                part = -part
                lo, hi = windows.get(part, (Fraction(-(1 << 62)), Fraction(1 << 62)))
                hi = min(hi, c)
                windows[part] = (lo, hi)
            else:
                # part_pos + c >= 0 => part_pos >= -c
                lo, hi = windows.get(part, (Fraction(-(1 << 62)), Fraction(1 << 62)))
                lo = max(lo, -c)
                windows[part] = (lo, hi)
        return any(lo > hi for lo, hi in windows.values())

    def _holds_float(self, assignment: Mapping[str, float]) -> bool:
        """Float screening (cheap); positives are re-verified exactly."""
        for a in self.atoms:
            v = a.poly.eval_float(assignment)
            if a.rel is Rel.GE and v < -1e-9:
                return False
            if a.rel is Rel.GT and v <= 1e-12:
                return False
            if a.rel is Rel.EQ and abs(v) > 1e-9:
                return False
        return True

    def check(self, *, seed: int = 0, samples: int = 4000) -> Verdict:
        if not self.atoms:
            return Verdict.CONSISTENT
        if any(a.trivially_false() for a in self.atoms):
            return Verdict.INCONSISTENT
        if self._pairwise_contradiction():
            return Verdict.INCONSISTENT
        box = self._propagate_bounds()
        if box is None:
            return Verdict.INCONSISTENT
        variables = sorted(self.variables())
        if not variables:
            return Verdict.CONSISTENT

        # --- witness search over the integer lattice inside the box ---------
        def candidates(var: str) -> List[Fraction]:
            lo, hi = box.get(var)
            lo_i = int(lo) if lo == int(lo) else int(lo) + 1
            hi_i = int(hi)
            vals: List[Fraction] = []
            for v in [lo_i, lo_i + 1, hi_i, hi_i - 1, 0, 1, 2]:
                if lo <= v <= hi:
                    vals.append(Fraction(v))
            p = 1
            while p <= hi_i and len(vals) < 40:
                if lo <= p:
                    vals.append(Fraction(p))
                p <<= 1
            # rational midpoints help for [0,1] performance measures
            mid = (lo + hi) / 2
            if lo <= mid <= hi:
                vals.append(mid)
            seen, out = set(), []
            for v in vals:
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

        cand = {v: candidates(v) for v in variables}
        rng = random.Random(seed)
        n_random = min(samples, 600)
        for trial in range(n_random):
            asg = {
                v: cand[v][trial % len(cand[v])] if trial < 8
                else rng.choice(cand[v])
                for v in variables
            }
            fasg = {k: float(x) for k, x in asg.items()}
            if self._holds_float(fasg) and self.holds(asg):
                self._last_witness = dict(asg)
                return Verdict.CONSISTENT
        return Verdict.UNKNOWN

    def is_consistent(self, **kw) -> bool:
        """Paper semantics: prune only on *proven* emptiness."""
        return self.check(**kw) is not Verdict.INCONSISTENT

    def witness(self, *, seed: int = 0, samples: int = 4000
                ) -> Optional[Dict[str, Fraction]]:
        """Return a satisfying assignment if the search finds one.

        First reuses the lattice-candidate search from :meth:`check` (bound
        endpoints + powers of two find small-product witnesses that uniform
        sampling over a 2^24 box essentially never hits), then falls back to
        log-uniform random sampling."""
        if not self.atoms:
            return {}
        if self.check(seed=seed) is Verdict.CONSISTENT:
            return dict(self._last_witness)
        variables = sorted(self.variables())
        box = self._propagate_bounds()
        if box is None:
            return None
        rng = random.Random(seed)
        for _ in range(samples):
            asg = {}
            for v in variables:
                lo, hi = box.get(v)
                lo_i, hi_i = int(lo), min(int(hi), _DEFAULT_HI)
                lo_i, hi_i = min(lo_i, hi_i), max(lo_i, hi_i)
                # log-uniform favours small values (paper domains are sizes)
                span = max(1, hi_i - lo_i)
                val = lo_i + int(2 ** (rng.random() * span.bit_length())) - 1
                asg[v] = Fraction(min(val, hi_i))
            if self.holds(asg):
                return asg
        return None

    def __repr__(self) -> str:
        return "{ " + " ;  ".join(map(repr, self.atoms)) + " }"

    def __len__(self) -> int:
        return len(self.atoms)

    # Value equality so artifact round-trips can assert leaf-for-leaf
    # identity.  Atom *order* is compared: conjunction semantics are
    # order-free, but serialization must preserve structure exactly.
    def __eq__(self, other) -> bool:
        if not isinstance(other, ConstraintSystem):
            return NotImplemented
        return self.atoms == other.atoms

    def __hash__(self) -> int:
        return hash(tuple(self.atoms))
