"""Kernel plans and the optimization quintuple (paper §3.6).

The paper's unit of work is a *quintuple* ``Q(S) = (G_C(S), λ, ω, γ, C)``:
the source CFG, the strategies already applied, the strategies still
available, the counters still to evaluate, and the constraint system built so
far.

On the TPU side the "code fragment" is a :class:`KernelPlan`: a symbolic
description of one Pallas kernel variant — which caching/granularity/CSE/
pressure transformations have been applied (``flags``) and which program
parameters remain symbolic (``program_params``).  A plan is *enough* to
(a) evaluate every resource/performance counter as a polynomial and
(b) instantiate a concrete ``pl.pallas_call`` once parameters are bound.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Mapping, Optional, Protocol, Sequence, Tuple

from .constraints import Constraint, ConstraintSystem
from .polynomial import Poly


@dataclass(frozen=True)
class ParamDomain:
    """Feasible values a program parameter may take at instantiation time."""

    name: str
    candidates: Tuple[int, ...]          # e.g. powers of two
    align: int = 1                       # hardware alignment requirement

    def feasible(self) -> Tuple[int, ...]:
        return tuple(c for c in self.candidates if c % self.align == 0)


@dataclass
class KernelPlan:
    """One symbolic kernel variant (the paper's code fragment S_i)."""

    family: str                                   # e.g. "matmul"
    flags: Dict[str, Any] = field(default_factory=dict)
    program_params: Dict[str, ParamDomain] = field(default_factory=dict)
    notes: List[str] = field(default_factory=list)

    def clone(self) -> "KernelPlan":
        return KernelPlan(
            family=self.family,
            flags=dict(self.flags),
            program_params=dict(self.program_params),
            notes=list(self.notes),
        )

    def with_flag(self, key: str, value: Any, note: str | None = None
                  ) -> "KernelPlan":
        p = self.clone()
        p.flags[key] = value
        if note:
            p.notes.append(note)
        return p

    def describe(self) -> str:
        flg = ", ".join(f"{k}={v}" for k, v in sorted(self.flags.items()))
        return f"{self.family}[{flg}]"


class FamilySpec(Protocol):
    """What a kernel family (kernels/<name>.py) must expose to the core."""

    name: str

    def initial_plan(self) -> KernelPlan: ...

    def counters(self) -> Sequence["Any"]:
        """Ordered resource+performance counters (core.counters.Counter)."""

    def strategies(self) -> Sequence["Any"]:
        """Ordered optimization strategies (core.strategies.Strategy)."""

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        """Symbolic (numerator, denominator) of a counter on this plan.
        Denominator must be positive on the domain (Remark 1)."""


@dataclass
class Quintuple:
    """Paper §3.6 ``Q(S)``; sequences behave as stacks (Remark 2)."""

    plan: KernelPlan                      # G_C(S) stand-in
    lam: List[str]                        # λ — applied strategies (history)
    omega: List[str]                      # ω — remaining strategy names (stack)
    gamma: List[str]                      # γ — remaining counter names (stack)
    C: ConstraintSystem                   # constraints accumulated so far

    def processed(self) -> bool:
        return not self.gamma

    def deepcopy(self) -> "Quintuple":
        return Quintuple(
            plan=self.plan.clone(),
            lam=list(self.lam),
            omega=list(self.omega),
            gamma=list(self.gamma),
            C=self.C.copy(),
        )


@dataclass(frozen=True)
class Leaf:
    """A processed quintuple == one (C_i, S_i) pair of Definition 2."""

    constraints: ConstraintSystem
    plan: KernelPlan
    applied: Tuple[str, ...]              # λ — the optimization recipe

    def __repr__(self) -> str:
        return (f"Leaf(plan={self.plan.describe()}, applied={self.applied}, "
                f"C={self.constraints})")
