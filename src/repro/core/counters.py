"""Resource and performance counters (paper §3.2-§3.3).

A counter pairs a *symbolic limit* (machine parameter ``R_i`` or ``P_i``)
with an *evaluation function* ``f_i``/``g_i`` mapping a kernel plan to a
polynomial (resource) or rational function (performance) in the program /
data / machine parameters — exactly the shape Remark 1 allows.

``sigma`` is the paper's ``σ(r_i)`` / ``σ(p_i)``: the subset of strategy
names with the potential to improve this counter.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Sequence, Tuple, TYPE_CHECKING

from .polynomial import Poly

if TYPE_CHECKING:  # pragma: no cover
    from .plan import FamilySpec, KernelPlan


class CounterKind(enum.Enum):
    RESOURCE = "resource"
    PERFORMANCE = "performance"


@dataclass(frozen=True)
class Counter:
    name: str
    kind: CounterKind
    limit_symbol: str                 # R_i name (resource) or P_i name (perf)
    sigma: Tuple[str, ...]            # strategies that may improve this counter
    doc: str = ""

    def evaluate(self, family: "FamilySpec", plan: "KernelPlan"
                 ) -> Tuple[Poly, Poly]:
        """Return (numerator, denominator) with denominator > 0 on-domain."""
        return family.counter_value(plan, self.name)


def resource(name: str, limit_symbol: str, sigma: Sequence[str], doc: str = ""
             ) -> Counter:
    return Counter(name, CounterKind.RESOURCE, limit_symbol, tuple(sigma), doc)


def performance(name: str, limit_symbol: str, sigma: Sequence[str],
                doc: str = "") -> Counter:
    return Counter(name, CounterKind.PERFORMANCE, limit_symbol, tuple(sigma),
                   doc)
