"""Load-time leaf selection and offline auto-tuning (paper §1, §4).

The comprehensive tree is built offline with every parameter symbolic.  When
the artifact is *loaded* on a concrete machine we:

1. substitute the machine bindings (``MachineDescription.bindings()``) into
   every leaf's constraint system and drop leaves that become inconsistent;
2. substitute the data parameters (matrix order, sequence length, ...);
3. enumerate feasible integer assignments of the remaining program
   parameters from their domains, filtered by the leaf constraints;
4. rank candidates with the paper-style performance counters (occupancy ×
   MXU utilization), entirely offline — or with a wall-clock ``runner`` when
   the caller wants empirical auto-tuning (benchmarks do this on CPU).

The cold path is served by the compiled-evaluation subsystem
(:mod:`repro.core.compiled`): each leaf's constraint system is specialized
against the machine+data binding *once*, the program-parameter cross-product
is materialized as integer arrays, and a vectorized screen decides all rows
at one go — only rows the float arithmetic cannot certify fall back to exact
``Fraction`` work.  ``use_compiled=False`` (or ``REPRO_COMPILED=0``) forces
the original per-candidate exact path, kept as the parity oracle; the
property tests assert both paths select identical candidates.

This file is what the rest of the framework calls: every perf-critical op
asks ``best_variant(family, machine, data)`` for its kernel configuration.
Ranking preference order: a *measured* (hardware-calibrated) rank from a
tuned dispatch table when one covers the bucket (``scripts/
tune_artifacts.py``, :mod:`repro.tuning`), else the symbolic offline model —
the fallback chain lives in :class:`repro.artifacts.dispatch.DispatchCache`.
"""
from __future__ import annotations

import itertools
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .compiled import CompiledSystem
from .comprehensive import comprehensive_tree
from .constraints import ConstraintSystem, Verdict
from .counters import CounterKind
from .params import MachineDescription
from .plan import FamilySpec, KernelPlan, Leaf

#: Process default for the vectorized cold path; REPRO_COMPILED=0 disables.
USE_COMPILED = os.environ.get("REPRO_COMPILED", "1").lower() not in (
    "0", "false", "no")


@dataclass
class SelectStats:
    """Process-wide instrumentation for the dispatch layers.

    ``enumerate_calls`` counts *cold* candidate enumerations — the expensive
    tree-search path the artifact/dispatch cache exists to amortize away.
    Tests assert on it; benchmarks report it.  The remaining fields profile
    the compiled cold path itself: how many rows went through the vectorized
    screen, how many needed the exact-Fraction fallback, and how many leaves
    could not be classified and ran the reference loop.
    """

    enumerate_calls: int = 0
    compiled_leaves: int = 0        # leaves decided by the vectorized screen
    fallback_leaves: int = 0        # leaves that ran the exact reference loop
    rows_screened: int = 0          # program-param rows batch-screened
    rows_emitted: int = 0           # candidates surviving screen + verify
    last_enumerate_seconds: float = 0.0

    def reset(self) -> None:
        self.enumerate_calls = 0
        self.compiled_leaves = 0
        self.fallback_leaves = 0
        self.rows_screened = 0
        self.rows_emitted = 0
        self.last_enumerate_seconds = 0.0


STATS = SelectStats()


@dataclass(frozen=True)
class Candidate:
    """A fully bound kernel variant ready to instantiate."""

    leaf_index: int
    plan: KernelPlan
    assignment: Dict[str, int]            # program-parameter values
    score: float                          # higher is better (offline model)

    def describe(self) -> str:
        asg = ", ".join(f"{k}={v}" for k, v in sorted(self.assignment.items()))
        return f"{self.plan.describe()} @ {{{asg}}} score={self.score:.4g}"


def specialize(leaves: Sequence[Leaf], machine: MachineDescription,
               data: Mapping[str, int]) -> List[Tuple[int, Leaf, ConstraintSystem]]:
    """Bind machine + data parameters; keep not-provably-inconsistent leaves."""
    binding = {**machine.bindings(), **{k: int(v) for k, v in data.items()}}
    kept = []
    for i, leaf in enumerate(leaves):
        C = leaf.constraints.subs(binding)
        if C.check() is not Verdict.INCONSISTENT:
            kept.append((i, leaf, C))
    return kept


def _perf_score(family: FamilySpec, plan: KernelPlan,
                values: Mapping[str, int]) -> float:
    """Offline model: product of performance-counter values clipped to 1.

    Families may provide a richer napkin-math model via ``score(plan, values)``
    (used for ranking only — feasibility always comes from the constraint
    tree, never from the score).
    """
    if hasattr(family, "score"):
        return float(family.score(plan, values))
    score = 1.0
    for c in family.counters():
        if c.kind is not CounterKind.PERFORMANCE:
            continue
        num, den = c.evaluate(family, plan)
        try:
            n = float(num.eval(values))
            d = float(den.eval(values))
        except KeyError:
            continue
        if d <= 0:
            return 0.0
        score *= min(1.0, max(0.0, n / d))
    return score


def _perf_score_batch(family: FamilySpec, plan: KernelPlan,
                      binding: Mapping[str, int],
                      cols: Mapping[str, np.ndarray],
                      n_rows: int) -> np.ndarray:
    """Batched scoring over ``n_rows`` program-parameter assignments.

    Families may expose ``score_batch(plan, values)`` over NumPy columns (the
    vectorized twin of ``score``); otherwise the scalar model runs per row —
    the row count here is already small (feasible candidates only)."""
    if hasattr(family, "score_batch"):
        values = {**binding, **{k: np.asarray(v) for k, v in cols.items()}}
        return np.broadcast_to(
            np.asarray(family.score_batch(plan, values), dtype=np.float64),
            (n_rows,))
    if hasattr(family, "score"):
        out = np.empty(n_rows, dtype=np.float64)
        for r in range(n_rows):
            values = {**binding, **{k: int(cols[k][r]) for k in cols}}
            out[r] = float(family.score(plan, values))
        return out
    # counter-product model, batched through the compiled evaluators
    score = np.ones(n_rows, dtype=np.float64)
    ccols = {**binding, **cols}
    for c in family.counters():
        if c.kind is not CounterKind.PERFORMANCE:
            continue
        num, den = c.evaluate(family, plan)
        try:
            n = np.broadcast_to(num.compile().eval_batch(ccols), (n_rows,))
            d = np.broadcast_to(den.compile().eval_batch(ccols), (n_rows,))
        except KeyError:
            continue
        bad = d <= 0
        ratio = np.clip(np.divide(n, d, out=np.zeros(n_rows),
                                  where=~bad), 0.0, 1.0)
        score = np.where(bad, 0.0, score * ratio)
    return score


# ---------------------------------------------------------------------------
# Cold-path enumeration: compiled (vectorized) and reference (exact) twins
# ---------------------------------------------------------------------------

def _enumerate_leaf_reference(family: FamilySpec, binding: Mapping[str, int],
                              idx: int, leaf: Leaf, C: ConstraintSystem,
                              max_per_leaf: int) -> List[Candidate]:
    """Original per-candidate exact loop for one machine+data-bound leaf."""
    out: List[Candidate] = []
    names = sorted(leaf.plan.program_params)
    domains = [leaf.plan.program_params[n].feasible() for n in names]
    count = 0
    for combo in itertools.product(*domains):
        if count >= max_per_leaf:
            break
        asg = dict(zip(names, combo))
        full = {**binding, **asg}
        # After machine+data+program binding the only free symbols are the
        # performance measures P_i in [0,1]; every atom is then constant
        # or univariate-linear, so the check below is a decision.
        if C.subs(asg).check(samples=64) is Verdict.INCONSISTENT:
            continue
        count += 1
        out.append(Candidate(
            leaf_index=idx,
            plan=leaf.plan,
            assignment=asg,
            score=_perf_score(family, leaf.plan, full),
        ))
    return out


#: Rows screened per vectorized batch: bounds peak memory on leaves whose
#: domain product is huge, and lets ``max_per_leaf`` stop the sweep early
#: (the reference loop's lazy-exit behaviour, chunked).
_SCREEN_CHUNK = 1 << 16


def _enumerate_leaf_compiled(family: FamilySpec, binding: Mapping[str, int],
                             idx: int, leaf: Leaf, cs: CompiledSystem,
                             max_per_leaf: int) -> List[Candidate]:
    """Vectorized enumeration of one leaf's program-parameter cross-product."""
    if cs.infeasible or max_per_leaf <= 0:
        return []
    names = sorted(leaf.plan.program_params)
    domains = [cs.filter_domain(n, leaf.plan.program_params[n].feasible())
               for n in names]
    if any(not d for d in domains):
        return []
    if not names:
        # no program parameters: the specialized system is fully decided
        score = _perf_score_batch(family, leaf.plan, binding, {}, 1)
        return [Candidate(leaf_index=idx, plan=leaf.plan, assignment={},
                          score=float(score[0]))]
    dom_arrays = [np.asarray(d, dtype=np.int64) for d in domains]
    shape = tuple(len(d) for d in domains)
    total = int(np.prod(shape))
    maxvals = {n: max(d) for n, d in zip(names, domains)}
    out: List[Candidate] = []
    # walk the cross-product in itertools.product order (C-order row ids),
    # one bounded chunk at a time
    for start in range(0, total, _SCREEN_CHUNK):
        stop = min(start + _SCREEN_CHUNK, total)
        multi = np.unravel_index(np.arange(start, stop), shape)
        cols = {n: dom_arrays[i][multi[i]] for i, n in enumerate(names)}
        n_rows = stop - start
        STATS.rows_screened += n_rows
        mask = cs.feasible_rows(cols, maxvals, n_rows)
        sel = np.flatnonzero(mask)[:max_per_leaf - len(out)]
        if sel.size:
            sel_cols = {n: cols[n][sel] for n in names}
            scores = _perf_score_batch(family, leaf.plan, binding, sel_cols,
                                       int(sel.size))
            for j in range(sel.size):
                asg = {n: int(sel_cols[n][j]) for n in names}
                out.append(Candidate(leaf_index=idx, plan=leaf.plan,
                                     assignment=asg, score=float(scores[j])))
        if len(out) >= max_per_leaf:
            break
    return out


def enumerate_candidates(family: FamilySpec,
                         machine: MachineDescription,
                         data: Mapping[str, int],
                         max_per_leaf: int = 512,
                         leaves: Optional[Sequence[Leaf]] = None,
                         use_compiled: Optional[bool] = None
                         ) -> List[Candidate]:
    """Cold-path enumeration over the comprehensive tree.

    ``leaves`` lets the artifact layer supply a disk-loaded tree instead of
    rebuilding in-process (the offline/online split of paper §1).
    ``use_compiled`` picks the vectorized cold path (default: module flag
    ``USE_COMPILED``); both paths return the identical candidate list — the
    reference path exists as the oracle the property tests compare against.
    """
    STATS.enumerate_calls += 1
    t0 = time.perf_counter()
    if use_compiled is None:
        use_compiled = USE_COMPILED
    binding = {**machine.bindings(), **{k: int(v) for k, v in data.items()}}
    if leaves is None:
        leaves = comprehensive_tree(family)
    out: List[Candidate] = []
    if use_compiled:
        for idx, leaf in enumerate(leaves):
            cs = leaf.constraints.specialize(binding)
            names = set(leaf.plan.program_params)
            if cs.fallback or not cs.row_vars <= names:
                # unclassifiable residual atoms (or residual symbols the
                # cross-product will not bind): exact loop for this leaf
                STATS.fallback_leaves += 1
                C = leaf.constraints.subs(binding)
                if C.check() is not Verdict.INCONSISTENT:
                    out.extend(_enumerate_leaf_reference(
                        family, binding, idx, leaf, C, max_per_leaf))
                continue
            STATS.compiled_leaves += 1
            out.extend(_enumerate_leaf_compiled(
                family, binding, idx, leaf, cs, max_per_leaf))
    else:
        for idx, leaf, C in specialize(leaves, machine, data):
            out.extend(_enumerate_leaf_reference(
                family, binding, idx, leaf, C, max_per_leaf))
    STATS.rows_emitted += len(out)
    STATS.last_enumerate_seconds = time.perf_counter() - t0
    return out


def rank_candidates(family: FamilySpec,
                    machine: MachineDescription,
                    data: Mapping[str, int],
                    leaves: Optional[Sequence[Leaf]] = None,
                    max_per_leaf: int = 512) -> List[Candidate]:
    """Enumerate + sort (best first).  Raises if nothing is feasible."""
    cands = enumerate_candidates(family, machine, data,
                                 max_per_leaf=max_per_leaf, leaves=leaves)
    if not cands:
        raise ValueError(
            f"no feasible kernel variant for family={family.name} "
            f"machine={machine.name} data={dict(data)}")
    cands.sort(key=lambda c: c.score, reverse=True)
    return cands


def best_variant(family: FamilySpec,
                 machine: MachineDescription,
                 data: Mapping[str, int],
                 runner: Optional[Callable[[Candidate], float]] = None,
                 top_k: int = 4,
                 *, use_cache: bool = True) -> Candidate:
    """Pick the kernel variant for this machine + data.

    The fully-static path (no ``runner``) is served by the process-wide
    :class:`repro.artifacts.dispatch.DispatchCache` — memory LRU, then disk
    artifact, then cold rebuild — so a recurring (family, machine, data)
    triple costs a dict lookup, not a tree search.  A disk table tuned by
    ``scripts/tune_artifacts.py`` carries measured per-bucket ranks; those
    take precedence over the symbolic score, falling back to the symbolic
    order for untuned tables/buckets.  ``use_cache=False`` forces
    the cold path (the cache itself uses it, as do A/B tests).

    ``runner`` (optional) measures wall-clock seconds for a candidate; when
    provided, the offline model shortlists ``top_k`` and the runner decides
    (classic auto-tuning, paper §1).  Empirical timings are machine-state
    dependent, so that path bypasses the cache.
    """
    if runner is None and use_cache:
        from ..artifacts.dispatch import get_default_cache
        return get_default_cache().best_variant(family, machine, data)
    cands = rank_candidates(family, machine, data)
    if runner is None:
        return cands[0]
    short = cands[:top_k]
    timed = [(runner(c), c) for c in short]
    timed.sort(key=lambda t: t[0])
    return timed[0][1]


def case_table(family: FamilySpec, machine: MachineDescription,
               datasets: Sequence[Mapping[str, int]]) -> List[Tuple[Dict, Candidate]]:
    """Paper Table-1-style report: best variant per input size."""
    return [(dict(d), best_variant(family, machine, d)) for d in datasets]
