"""Load-time leaf selection and offline auto-tuning (paper §1, §4).

The comprehensive tree is built offline with every parameter symbolic.  When
the artifact is *loaded* on a concrete machine we:

1. substitute the machine bindings (``MachineDescription.bindings()``) into
   every leaf's constraint system and drop leaves that become inconsistent;
2. substitute the data parameters (matrix order, sequence length, ...);
3. enumerate feasible integer assignments of the remaining program
   parameters from their domains, filtered by the leaf constraints;
4. rank candidates with the paper-style performance counters (occupancy ×
   MXU utilization), entirely offline — or with a wall-clock ``runner`` when
   the caller wants empirical auto-tuning (benchmarks do this on CPU).

This file is what the rest of the framework calls: every perf-critical op
asks ``best_variant(family, machine, data)`` for its kernel configuration.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from fractions import Fraction
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .comprehensive import comprehensive_tree
from .constraints import ConstraintSystem, Verdict
from .counters import Counter, CounterKind
from .params import MachineDescription
from .plan import FamilySpec, KernelPlan, Leaf


@dataclass
class SelectStats:
    """Process-wide instrumentation for the dispatch layers.

    ``enumerate_calls`` counts *cold* candidate enumerations — the expensive
    tree-search path the artifact/dispatch cache exists to amortize away.
    Tests assert on it; benchmarks report it.
    """

    enumerate_calls: int = 0

    def reset(self) -> None:
        self.enumerate_calls = 0


STATS = SelectStats()


@dataclass(frozen=True)
class Candidate:
    """A fully bound kernel variant ready to instantiate."""

    leaf_index: int
    plan: KernelPlan
    assignment: Dict[str, int]            # program-parameter values
    score: float                          # higher is better (offline model)

    def describe(self) -> str:
        asg = ", ".join(f"{k}={v}" for k, v in sorted(self.assignment.items()))
        return f"{self.plan.describe()} @ {{{asg}}} score={self.score:.4g}"


def specialize(leaves: Sequence[Leaf], machine: MachineDescription,
               data: Mapping[str, int]) -> List[Tuple[int, Leaf, ConstraintSystem]]:
    """Bind machine + data parameters; keep not-provably-inconsistent leaves."""
    binding = {**machine.bindings(), **{k: int(v) for k, v in data.items()}}
    kept = []
    for i, leaf in enumerate(leaves):
        C = leaf.constraints.subs(binding)
        if C.check() is not Verdict.INCONSISTENT:
            kept.append((i, leaf, C))
    return kept


def _perf_score(family: FamilySpec, plan: KernelPlan,
                values: Mapping[str, int]) -> float:
    """Offline model: product of performance-counter values clipped to 1.

    Families may provide a richer napkin-math model via ``score(plan, values)``
    (used for ranking only — feasibility always comes from the constraint
    tree, never from the score).
    """
    if hasattr(family, "score"):
        return float(family.score(plan, values))
    score = 1.0
    for c in family.counters():
        if c.kind is not CounterKind.PERFORMANCE:
            continue
        num, den = c.evaluate(family, plan)
        try:
            n = float(num.eval(values))
            d = float(den.eval(values))
        except KeyError:
            continue
        if d <= 0:
            return 0.0
        score *= min(1.0, max(0.0, n / d))
    return score


def enumerate_candidates(family: FamilySpec,
                         machine: MachineDescription,
                         data: Mapping[str, int],
                         max_per_leaf: int = 512,
                         leaves: Optional[Sequence[Leaf]] = None
                         ) -> List[Candidate]:
    """Cold-path enumeration over the comprehensive tree.

    ``leaves`` lets the artifact layer supply a disk-loaded tree instead of
    rebuilding in-process (the offline/online split of paper §1).
    """
    STATS.enumerate_calls += 1
    binding = {**machine.bindings(), **{k: int(v) for k, v in data.items()}}
    if leaves is None:
        leaves = comprehensive_tree(family)
    out: List[Candidate] = []
    for idx, leaf, C in specialize(leaves, machine, data):
        names = sorted(leaf.plan.program_params)
        domains = [leaf.plan.program_params[n].feasible() for n in names]
        count = 0
        for combo in itertools.product(*domains):
            if count >= max_per_leaf:
                break
            asg = dict(zip(names, combo))
            full = {**binding, **asg}
            # After machine+data+program binding the only free symbols are the
            # performance measures P_i in [0,1]; every atom is then constant
            # or univariate-linear, so the check below is a decision.
            if C.subs(asg).check(samples=64) is Verdict.INCONSISTENT:
                continue
            count += 1
            out.append(Candidate(
                leaf_index=idx,
                plan=leaf.plan,
                assignment=asg,
                score=_perf_score(family, leaf.plan, full),
            ))
    return out


def rank_candidates(family: FamilySpec,
                    machine: MachineDescription,
                    data: Mapping[str, int],
                    leaves: Optional[Sequence[Leaf]] = None,
                    max_per_leaf: int = 512) -> List[Candidate]:
    """Enumerate + sort (best first).  Raises if nothing is feasible."""
    cands = enumerate_candidates(family, machine, data,
                                 max_per_leaf=max_per_leaf, leaves=leaves)
    if not cands:
        raise ValueError(
            f"no feasible kernel variant for family={family.name} "
            f"machine={machine.name} data={dict(data)}")
    cands.sort(key=lambda c: c.score, reverse=True)
    return cands


def best_variant(family: FamilySpec,
                 machine: MachineDescription,
                 data: Mapping[str, int],
                 runner: Optional[Callable[[Candidate], float]] = None,
                 top_k: int = 4,
                 *, use_cache: bool = True) -> Candidate:
    """Pick the kernel variant for this machine + data.

    The fully-static path (no ``runner``) is served by the process-wide
    :class:`repro.artifacts.dispatch.DispatchCache` — memory LRU, then disk
    artifact, then cold rebuild — so a recurring (family, machine, data)
    triple costs a dict lookup, not a tree search.  ``use_cache=False`` forces
    the cold path (the cache itself uses it, as do A/B tests).

    ``runner`` (optional) measures wall-clock seconds for a candidate; when
    provided, the offline model shortlists ``top_k`` and the runner decides
    (classic auto-tuning, paper §1).  Empirical timings are machine-state
    dependent, so that path bypasses the cache.
    """
    if runner is None and use_cache:
        from ..artifacts.dispatch import get_default_cache
        return get_default_cache().best_variant(family, machine, data)
    cands = rank_candidates(family, machine, data)
    if runner is None:
        return cands[0]
    short = cands[:top_k]
    timed = [(runner(c), c) for c in short]
    timed.sort(key=lambda t: t[0])
    return timed[0][1]


def case_table(family: FamilySpec, machine: MachineDescription,
               datasets: Sequence[Mapping[str, int]]) -> List[Tuple[Dict, Candidate]]:
    """Paper Table-1-style report: best variant per input size."""
    return [(dict(d), best_variant(family, machine, d)) for d in datasets]
