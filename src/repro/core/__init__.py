"""Comprehensive optimization of parametric kernels (the paper's contribution).

Public API:

- :mod:`repro.core.polynomial`    — exact multivariate polynomials over Q
- :mod:`repro.core.compiled`      — compiled batch evaluation (NumPy) of
  polynomials and specialized constraint systems
- :mod:`repro.core.constraints`   — semi-algebraic systems + consistency
- :mod:`repro.core.params`        — machine/program/data parameter symbols
- :mod:`repro.core.plan`          — kernel plans + the optimization quintuple
- :mod:`repro.core.counters`      — resource/performance counters (f_i, g_i)
- :mod:`repro.core.strategies`    — optimization strategies O_1..O_w
- :mod:`repro.core.comprehensive` — Algorithms 1 & 2 (the decision tree)
- :mod:`repro.core.select`        — load-time leaf selection + auto-tuning
"""
from .polynomial import Poly, V
from .compiled import CompiledPoly, CompiledSystem, specialize_system
from .constraints import (Constraint, ConstraintSystem, Rel, Verdict,
                          is_integer_var)
from .params import (MachineDescription, MACHINES, TPU_V5E, PAPER_M2050,
                     ParamKind, ParamSymbol)
from .plan import FamilySpec, KernelPlan, Leaf, ParamDomain, Quintuple
from .counters import Counter, CounterKind, performance, resource
from .strategies import Strategy, level_strategy, toggle_strategy
from .comprehensive import (comprehensive_optimization, comprehensive_tree,
                            initial_quintuple, optimize, tree_report)
from .select import (STATS, Candidate, SelectStats, best_variant, case_table,
                     enumerate_candidates, rank_candidates)

__all__ = [
    "Poly", "V", "CompiledPoly", "CompiledSystem", "specialize_system",
    "Constraint", "ConstraintSystem", "Rel", "Verdict", "is_integer_var",
    "MachineDescription", "MACHINES", "TPU_V5E", "PAPER_M2050",
    "ParamKind", "ParamSymbol", "FamilySpec", "KernelPlan", "Leaf",
    "ParamDomain", "Quintuple", "Counter", "CounterKind", "performance",
    "resource", "Strategy", "level_strategy", "toggle_strategy",
    "comprehensive_optimization", "comprehensive_tree", "initial_quintuple",
    "optimize", "tree_report", "Candidate", "best_variant", "case_table",
    "enumerate_candidates", "rank_candidates", "SelectStats", "STATS",
]
