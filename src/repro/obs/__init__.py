"""Decision-provenance tracing + unified metrics registry.

The paper's artifact is a *case discussion*: every kernel launch is the
result of a branch taken through the comprehensive tree at concrete
(machine, program) parameter values.  This package makes that decision —
and the serving stack's operational decisions around it — observable as
one joinable event stream plus one snapshot API:

* :mod:`repro.obs.events` — the event taxonomy (``TickSpan``,
  ``DispatchDecision``, ``FaultFired``, ``PrefixHit``,
  ``AdmissionDecision``; the monitor's ``SwapEvent`` and the cache's
  ``DegradeEvent`` join the stream as-is), the JSONL schema + validator,
  and the shared transition renderer both ``describe()``s delegate to.
* :mod:`repro.obs.recorder` — the flight recorder: a bounded ring of
  events with monotonic sequence ids and byte-deterministic JSONL
  export, installed process-wide exactly like
  :mod:`repro.runtime.faults`' injector (one module-global load when
  tracing is off).
* :mod:`repro.obs.registry` — :class:`ObsRegistry`: the stats
  dataclasses scattered across pool/scheduler/dispatch/monitor/watchdog
  unified behind ``snapshot()`` / ``render_text()`` / ``summary_line()``.

Everything here is stdlib-only so the light modules (``runtime.faults``,
``artifacts.dispatch``, ``runtime.kv_pool``) can import it at module
scope without pulling jax or the engine in.
"""
from .events import (EVENT_SCHEMA, AdmissionDecision, DispatchDecision,
                     FaultFired, PrefixHit, TickSpan, describe_transition,
                     event_record, validate_record)
from .recorder import (FlightRecorder, emit, get_recorder, install, set_tick,
                       tracing)
from .registry import ObsRegistry

__all__ = [
    "EVENT_SCHEMA", "AdmissionDecision", "DispatchDecision", "FaultFired",
    "PrefixHit", "TickSpan", "describe_transition", "event_record",
    "validate_record", "FlightRecorder", "emit", "get_recorder", "install",
    "set_tick", "tracing", "ObsRegistry",
]
