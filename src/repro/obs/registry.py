"""The unified metrics registry over the serving stack's stats surfaces.

``PoolStats``, ``SchedStats``, ``DispatchStats``, ``MonitorStats``,
``WatchdogStats``, and the flight recorder each count their own corner;
:class:`ObsRegistry` joins them behind one snapshot API:

* :meth:`ObsRegistry.snapshot` — nested plain dict (JSON-ready);
* :meth:`ObsRegistry.render_text` — Prometheus-style text exposition
  (``repro_<group>_<name> <value>`` lines, sorted);
* :meth:`ObsRegistry.summary_line` — the one-line operator summary that
  replaces the scattered prints in ``launch/serve.py``;
* :meth:`ObsRegistry.kernel_report` — per-kernel provenance lines read
  from the *current* frozen plan (post-swap/post-demote picks with their
  live source and demotion marks, not the warm-up snapshot).

Construction is by parts or :meth:`from_engine`; either way the parts
are re-read at snapshot time, so a monitor attached or a plan republished
after construction is reported, not the stale reference.
"""
from __future__ import annotations

from dataclasses import asdict, is_dataclass
from typing import Any, Dict, List, Optional


def _stats_dict(obj: Any) -> Dict[str, Any]:
    if obj is None:
        return {}
    if is_dataclass(obj) and not isinstance(obj, type):
        return dict(asdict(obj))
    if hasattr(obj, "as_dict"):
        return dict(obj.as_dict())
    return {}


class ObsRegistry:
    """One snapshot surface over pool/scheduler/dispatch/monitor/watchdog
    stats plus the flight recorder."""

    def __init__(self, *, engine: Any = None, pool: Any = None,
                 sched: Any = None, cache: Any = None, monitor: Any = None,
                 watchdog: Any = None, recorder: Any = None):
        self._engine = engine
        self._pool = pool
        self._sched = sched
        self._cache = cache
        self._monitor = monitor
        self._watchdog = watchdog
        self._recorder = recorder

    @classmethod
    def from_engine(cls, engine: Any,
                    recorder: Any = None) -> "ObsRegistry":
        """Bind to a :class:`repro.runtime.serving.ServeEngine`; parts are
        resolved per snapshot, so late-attached pieces are picked up."""
        return cls(engine=engine, recorder=recorder)

    # -- part resolution (engine-bound parts win) -----------------------------
    def _part(self, name: str, attr: str) -> Any:
        if self._engine is not None:
            return getattr(self._engine, attr, None)
        return getattr(self, name)

    @property
    def pool(self) -> Any:
        return self._part("_pool", "pool")

    @property
    def sched(self) -> Any:
        return self._part("_sched", "sched")

    @property
    def cache(self) -> Any:
        return self._part("_cache", "_cache")

    @property
    def monitor(self) -> Any:
        return self._part("_monitor", "monitor")

    @property
    def watchdog(self) -> Any:
        return self._part("_watchdog", "watchdog")

    @property
    def recorder(self) -> Any:
        if self._recorder is not None:
            return self._recorder
        from . import recorder as _rec
        return _rec.get_recorder()

    # -- snapshot -------------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Nested dict of every attached surface's counters plus derived
        gauges.  Sections for absent parts are empty dicts, so consumers
        can iterate without presence checks."""
        out: Dict[str, Dict[str, Any]] = {}
        pool = self.pool
        out["pool"] = _stats_dict(getattr(pool, "stats", None))
        if pool is not None:
            out["pool"].update(capacity=pool.capacity,
                               num_free=pool.num_free,
                               num_live=pool.num_live,
                               page_size=pool.page_size)
        sched = self.sched
        out["sched"] = _stats_dict(getattr(sched, "stats", None))
        if sched is not None:
            out["sched"].update(ticks=sched.ticks,
                                queue_depth=len(sched.queue),
                                running=len(sched.running()))
        cache = self.cache
        out["dispatch"] = _stats_dict(getattr(cache, "stats", None))
        if cache is not None:
            plan = cache.frozen_plan
            out["dispatch"].update(
                frozen_entries=len(plan) if plan is not None else 0,
                degrade_events=len(cache.degrade_events))
        mon = self.monitor
        out["monitor"] = _stats_dict(getattr(mon, "stats", None))
        if mon is not None:
            out["monitor"]["swap_events"] = len(mon.events)
        out["watchdog"] = _stats_dict(
            getattr(self.watchdog, "stats", None))
        rec = self.recorder
        out["recorder"] = ({} if rec is None else {
            "emitted": rec.emitted, "buffered": len(rec),
            "dropped": rec.dropped, "capacity": rec.capacity,
            "sample_frozen_every": rec.sample_frozen_every})
        return out

    # -- renderings -----------------------------------------------------------
    def render_text(self) -> str:
        """Prometheus-style exposition: one ``repro_<group>_<name> <value>``
        line per numeric counter/gauge, sorted for stable diffs."""
        lines: List[str] = []
        for group, section in sorted(self.snapshot().items()):
            for name, value in sorted(section.items()):
                if isinstance(value, bool) or not isinstance(value,
                                                             (int, float)):
                    continue
                v = f"{value:.6g}" if isinstance(value, float) else str(value)
                lines.append(f"repro_{group}_{name} {v}")
        return "\n".join(lines) + ("\n" if lines else "")

    def summary_line(self) -> str:
        """The operator one-liner: each attached surface's headline
        counters, ``|``-separated (the unified replacement for the
        scattered prints ``launch/serve.py`` used to build by hand)."""
        s = self.snapshot()
        parts: List[str] = []
        if s["sched"]:
            d = s["sched"]
            parts.append(
                f"ticks={d.get('ticks', 0)} adm={d.get('admissions', 0)} "
                f"wait={d.get('admission_waits', 0)} "
                f"preempt={d.get('preemptions', 0)} shed={d.get('shed', 0)} "
                f"cancel={d.get('cancelled', 0)} "
                f"poison={d.get('poisoned', 0)}")
        if s["pool"]:
            d = s["pool"]
            parts.append(
                f"pool live={d.get('num_live', 0)}/{d.get('capacity', 0)} "
                f"peak={d.get('peak_live', 0)} "
                f"prefix_hits={d.get('prefix_hits', 0)} "
                f"saved={d.get('prefix_tokens_saved', 0)} "
                f"cow={d.get('cow_copies', 0)} "
                f"evict={d.get('cache_evictions', 0)}")
        if s["dispatch"]:
            d = s["dispatch"]
            parts.append(
                f"dispatch frozen={d.get('frozen_entries', 0)} "
                f"mem={d.get('memory_hits', 0)} disk={d.get('disk_hits', 0)} "
                f"cold={d.get('cold_builds', 0)} "
                f"demote={d.get('demotions', 0)}")
        if s["monitor"]:
            d = s["monitor"]
            blocked = (d.get("swap_blocked_infeasible", 0)
                       + d.get("swap_blocked_gen", 0))
            parts.append(
                f"monitor probes={d.get('probes', 0)} "
                f"swaps={d.get('swaps', 0)} blocked={blocked}")
        if s["watchdog"]:
            d = s["watchdog"]
            parts.append(f"watchdog slow={d.get('slow_ticks', 0)} "
                         f"worst={d.get('worst_ratio', 0.0):.1f}x")
        if s["recorder"]:
            d = s["recorder"]
            parts.append(f"trace n={d.get('emitted', 0)} "
                         f"dropped={d.get('dropped', 0)}")
        return "obs " + " | ".join(parts) if parts else "obs (no surfaces)"

    def kernel_report(self) -> List[str]:
        """Per-kernel provenance lines from the *current* frozen plan:
        label, live candidate, the source that decided it (``measured``
        after a monitor swap, even if warm-up said ``symbolic``), and any
        demotion marks in effect.  Empty without a frozen plan."""
        cache = self.cache
        plan = getattr(cache, "frozen_plan", None)
        if plan is None:
            return []
        from ..plans.trace import op_label
        lines = []
        for family, machine, data in plan.triples:
            ent = plan.get(family.name, machine.name, data)
            if ent is None:
                continue
            label = op_label(family.name, dict(data))
            marks = cache.demoted_keys(family.name, machine.name, data)
            tail = f" demoted_marks={len(marks)}" if marks else ""
            lines.append(f"kernel {label} [{ent.source}]: "
                         f"{ent.candidate.describe()}{tail}")
        return sorted(lines)
