"""Trace event taxonomy, JSONL schema, and the shared transition renderer.

Events are plain frozen dataclasses.  The flight recorder serializes any
dataclass whose type name appears in :data:`EVENT_TYPES` — the monitor's
``SwapEvent`` and the dispatch cache's ``DegradeEvent`` join the stream
without this module importing either (no numpy, no cycles): the mapping
is by class *name*, the fields by ``dataclasses.fields``.

Determinism contract: every field value is an int, float, str, bool, or
a (possibly nested) tuple of those — ``json.dumps(sort_keys=True)`` over
them is byte-stable across runs.  Timestamps are tick indices;
``TickSpan.duration_us`` is the only wall-clock-derived field and it
comes from the engine's *injectable* clock, so CI runs under a counting
clock are byte-identical end to end.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Any, Dict, Mapping, Tuple

#: class name -> etype tag carried on every JSONL record.
EVENT_TYPES: Dict[str, str] = {
    "TickSpan": "tick_span",
    "DispatchDecision": "dispatch_decision",
    "SwapEvent": "swap",
    "DegradeEvent": "degrade",
    "FaultFired": "fault_fired",
    "PrefixHit": "prefix_hit",
    "AdmissionDecision": "admission_decision",
}


@dataclass(frozen=True)
class TickSpan:
    """One engine tick's shape: what the plan scheduled, what committed,
    how long the host-side step took (on the engine's injectable clock)."""

    tick: int
    admitted: int
    prefill_tokens: int
    decode_rows: int
    preempted: int
    cancelled: int
    finished: int                 # requests that completed this step
    duration_us: float


@dataclass(frozen=True)
class DispatchDecision:
    """The decision-provenance record: which case-discussion branch one
    non-frozen dispatch took.  ``surface`` is the entry point
    (``resolve`` = locked tiers via ``best_variant*``/``warm_callable``
    miss, ``frozen`` = fast-lane hit, ``warm_sampled`` = 1-in-N sample of
    the uncounted ``warm_callable`` lane); ``rank`` is the candidate's
    position in the ranking that decided it (0 = top pick, -1 = replayed
    from the memory LRU where the walk index was not retained);
    ``demoted`` counts the triple's runtime-broken marks in effect."""

    tick: int
    family: str
    machine: str
    data: Tuple[Tuple[str, int], ...]        # sorted items
    bucket: str
    leaf: int
    assignment: Tuple[Tuple[str, int], ...]  # sorted items
    source: str                              # measured | symbolic | cold | frozen
    surface: str                             # resolve | frozen | warm_sampled
    rank: int
    demoted: int


@dataclass(frozen=True)
class FaultFired:
    """One chaos-schedule spec consumed by an injection site."""

    tick: int
    site: str
    kind: str
    arg: int


@dataclass(frozen=True)
class PrefixHit:
    """One committed prefix-index match: blocks mapped instead of
    recomputed, token positions served from the index."""

    tick: int
    blocks: int
    tokens: int


@dataclass(frozen=True)
class AdmissionDecision:
    """One scheduler decision about a request: ``action`` is ``admit`` |
    ``wait`` (head-of-line blocked on head-room) | ``shed`` (queue bound)
    | ``preempt`` (pool pressure eviction) | ``poison`` (fault
    preemption) | ``cancel`` (deadline)."""

    tick: int
    action: str
    rid: int
    slot: int                     # -1 when the request holds no slot
    queue_depth: int


#: etype -> {field name -> allowed python types}.  ``seq`` and ``etype``
#: are stamped by the recorder on every record.
EVENT_SCHEMA: Dict[str, Dict[str, Tuple[type, ...]]] = {
    "tick_span": {
        "tick": (int,), "admitted": (int,), "prefill_tokens": (int,),
        "decode_rows": (int,), "preempted": (int,), "cancelled": (int,),
        "finished": (int,), "duration_us": (int, float),
    },
    "dispatch_decision": {
        "tick": (int,), "family": (str,), "machine": (str,),
        "data": (list, tuple), "bucket": (str,), "leaf": (int,),
        "assignment": (list, tuple), "source": (str,), "surface": (str,),
        "rank": (int,), "demoted": (int,),
    },
    "swap": {
        "tick": (int,), "family": (str,), "data": (list, tuple),
        "old": (list, tuple), "new": (list, tuple),
        "incumbent_us": (int, float), "challenger_us": (int, float),
        "windows": (int,),
    },
    "degrade": {
        "tick": (int,), "family": (str,), "machine": (str,),
        "data": (list, tuple), "old": (list, tuple), "new": (list, tuple),
        "error": (str,), "source": (str,), "exhausted": (bool,),
    },
    "fault_fired": {
        "tick": (int,), "site": (str,), "kind": (str,), "arg": (int,),
    },
    "prefix_hit": {
        "tick": (int,), "blocks": (int,), "tokens": (int,),
    },
    "admission_decision": {
        "tick": (int,), "action": (str,), "rid": (int,), "slot": (int,),
        "queue_depth": (int,),
    },
}

_ACTIONS = ("admit", "wait", "shed", "preempt", "poison", "cancel")
_SURFACES = ("resolve", "frozen", "warm_sampled")


def event_record(event: Any, seq: int, tick: int) -> Dict[str, Any]:
    """Flatten one event dataclass to a JSONL-ready dict.  ``tick`` is the
    recorder's cursor, used only when the event carries no tick of its
    own; ``seq`` is the recorder-assigned monotonic id."""
    name = type(event).__name__
    etype = EVENT_TYPES.get(name)
    if etype is None:
        raise TypeError(f"not a registered trace event: {name}")
    rec: Dict[str, Any] = {"seq": int(seq), "etype": etype}
    for f in fields(event):
        rec[f.name] = getattr(event, f.name)
    rec.setdefault("tick", int(tick))
    return rec


def validate_record(rec: Mapping[str, Any]) -> None:
    """Raise ``ValueError`` unless ``rec`` is a well-formed trace record:
    known etype, non-negative monotonic-assignable seq, every schema
    field present with an allowed type, no unknown fields."""
    etype = rec.get("etype")
    schema = EVENT_SCHEMA.get(etype)  # type: ignore[arg-type]
    if schema is None:
        raise ValueError(f"unknown etype: {etype!r}")
    if not isinstance(rec.get("seq"), int) or rec["seq"] < 0:
        raise ValueError(f"bad seq: {rec.get('seq')!r}")
    allowed = set(schema) | {"seq", "etype"}
    extra = set(rec) - allowed
    if extra:
        raise ValueError(f"{etype}: unknown fields {sorted(extra)}")
    for name, types in schema.items():
        if name not in rec:
            raise ValueError(f"{etype}: missing field {name!r}")
        v = rec[name]
        if bool in types:
            ok = isinstance(v, bool)
        else:
            ok = isinstance(v, types) and not isinstance(v, bool)
        if not ok:
            raise ValueError(
                f"{etype}.{name}: {type(v).__name__} not in "
                f"{tuple(t.__name__ for t in types)}")
    if etype == "admission_decision" and rec["action"] not in _ACTIONS:
        raise ValueError(f"admission_decision.action: {rec['action']!r}")
    if etype == "dispatch_decision" and rec["surface"] not in _SURFACES:
        raise ValueError(f"dispatch_decision.surface: {rec['surface']!r}")


def describe_transition(*, tick: int, verb: str, family: str,
                        data: Tuple[Tuple[str, int], ...],
                        old: str, new: str, note: str = "",
                        cause: str = "", tail: str = "") -> str:
    """The one event-rendering convention for candidate transitions.

    ``tick N: <verb> family@k=v,... OLD -> NEW (note) after CAUSE<tail>``

    Both :meth:`repro.runtime.monitor.SwapEvent.describe` and
    :meth:`repro.artifacts.dispatch.DegradeEvent.describe` delegate here
    (a test pins the exact format), so the two logs cannot drift."""
    dims = ",".join(f"{k}={v}" for k, v in data)
    out = f"tick {tick}: {verb} {family}@{dims} {old} -> {new}"
    if note:
        out += f" ({note})"
    if cause:
        out += f" after {cause}"
    return out + tail
