"""The flight recorder: a bounded, lock-cheap ring of trace events.

Mirrors :mod:`repro.runtime.faults`' process-wide injector idiom: a
module-global recorder consulted by instrumented sites.  When tracing is
off (the production default) a site costs one module-global load plus an
``is None`` test — no counters, no allocation.  When tracing is on,
emitting appends one record dict to a ``collections.deque(maxlen=...)``:
appends and the aging-out of old records are GIL-atomic, so the hot
paths take no lock (the ring is a single-writer-ish observability
surface, not a concurrency primitive — same stance as ``DispatchStats``'
lock-free ``frozen_hits``).

The frozen ``warm_callable`` lane is *uncounted by default* even while
tracing (PR 4 perf contract): ``sample_frozen_every=N`` opts into a
1-in-N sample of that lane, surfaced as ``dispatch_decision`` records
with ``surface="warm_sampled"``.

Export is byte-deterministic: records carry tick indices (never wall
clock — ``TickSpan.duration_us`` comes from the engine's injectable
clock), sequence ids are assigned in emission order, and JSONL encoding
is ``sort_keys=True, separators=(",", ":")`` — same seed + same schedule
means byte-identical output (``scripts/ci_obs.py`` gates this).
"""
from __future__ import annotations

import collections
import contextlib
import json
from typing import Any, Dict, Iterator, List, Optional

from .events import DispatchDecision, event_record


def _jsonable(v: Any) -> Any:
    """Tuples -> lists so exported records equal their json round-trip."""
    if isinstance(v, tuple):
        return [_jsonable(x) for x in v]
    return v


class FlightRecorder:
    """Bounded ring of trace events with monotonic sequence ids.

    ``capacity`` bounds memory: the oldest records age out first and are
    counted in :attr:`dropped` (reported, never silent).  ``emitted`` is
    the lifetime count; ``seq`` ids keep climbing across drops, so a
    truncated trace is detectable from the records alone."""

    def __init__(self, capacity: int = 4096,
                 sample_frozen_every: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        if sample_frozen_every < 0:
            raise ValueError(
                f"sample_frozen_every must be >= 0: {sample_frozen_every}")
        self.capacity = int(capacity)
        #: 0 = the frozen warm lane stays uncounted (default); N>0 =
        #: record every N-th warm_callable hit as a sampled decision.
        self.sample_frozen_every = int(sample_frozen_every)
        self.tick = 0
        self.emitted = 0
        self._ring: "collections.deque[Dict[str, Any]]" = \
            collections.deque(maxlen=self.capacity)
        self._warm_calls = 0

    # -- emission (hot-path side) --------------------------------------------
    def emit(self, event: Any) -> None:
        """Append one event (any registered dataclass; see
        :data:`repro.obs.events.EVENT_TYPES`)."""
        rec = event_record(event, self.emitted, self.tick)
        self.emitted += 1
        self._ring.append(rec)

    def sample_warm(self, family_name: str, machine_name: str,
                    items: Any) -> None:
        """1-in-N sampling hook for the frozen ``warm_callable`` lane.
        Callers gate on ``sample_frozen_every > 0`` before calling, so
        the default-sampling trace never touches this counter."""
        self._warm_calls += 1
        if self._warm_calls % self.sample_frozen_every:
            return
        data = tuple(sorted((k, int(v)) for k, v in dict(items).items()))
        self.emit(DispatchDecision(
            tick=self.tick, family=family_name, machine=machine_name,
            data=data, bucket="", leaf=-1, assignment=(),
            source="frozen", surface="warm_sampled", rank=0, demoted=0))

    # -- reading / export -----------------------------------------------------
    @property
    def dropped(self) -> int:
        """Records aged out of the ring (emitted but no longer held)."""
        return self.emitted - len(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def records(self) -> List[Dict[str, Any]]:
        """Snapshot of the buffered records, oldest first, with tuples
        normalized to lists (identical to a JSONL round-trip)."""
        return [{k: _jsonable(v) for k, v in rec.items()}
                for rec in list(self._ring)]

    def export_jsonl(self) -> str:
        """Byte-deterministic JSONL: one record per line, sorted keys,
        minimal separators, trailing newline when non-empty."""
        lines = [json.dumps(rec, sort_keys=True, separators=(",", ":"))
                 for rec in self.records()]
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# The process-wide recorder (None when tracing is off: sites cost one
# module-global load — the faults-injector idiom).
# ---------------------------------------------------------------------------

_recorder: Optional[FlightRecorder] = None


def install(recorder: Optional[FlightRecorder]) -> None:
    global _recorder
    _recorder = recorder


def get_recorder() -> Optional[FlightRecorder]:
    return _recorder


def set_tick(tick: int) -> None:
    """Advance the installed recorder's tick cursor (the engine calls
    this at the top of every step; no-op when tracing is off)."""
    if _recorder is not None:
        _recorder.tick = int(tick)


def emit(event: Any) -> None:
    """Emit through the installed recorder; no-op when tracing is off.
    Hot paths inline the global test instead of paying this call."""
    if _recorder is not None:
        _recorder.emit(event)


@contextlib.contextmanager
def tracing(capacity: int = 4096, sample_frozen_every: int = 0
            ) -> Iterator[FlightRecorder]:
    """Install a fresh recorder for the duration of the block
    (tests/CI drills); always restores the previous one on exit."""
    rec = FlightRecorder(capacity=capacity,
                         sample_frozen_every=sample_frozen_every)
    prev = _recorder
    install(rec)
    try:
        yield rec
    finally:
        install(prev)
