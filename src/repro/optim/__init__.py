"""Optimizers, schedules, gradient clipping."""
from .optimizers import (Optimizer, adafactor, adamw, clip_by_global_norm,
                         constant, global_norm, make_optimizer, warmup_cosine)

__all__ = ["Optimizer", "adafactor", "adamw", "clip_by_global_norm",
           "constant", "global_norm", "make_optimizer", "warmup_cosine"]
