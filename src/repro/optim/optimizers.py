"""Sharded-friendly optimizers: AdamW and Adafactor, plus clipping/schedules.

Pure-pytree implementations (no optax dependency — the container is offline).
Every state leaf mirrors its parameter's shape (AdamW) or factors it
(Adafactor), so the distributed layer can shard optimizer state with the
same (or coarser, ZeRO-1) rules as the parameters.

Interface::

    opt = adamw(lr_schedule, weight_decay=0.1)
    state = opt.init(params)
    params, state = opt.update(grads, state, params, step)

``step`` is a traced scalar; the learning rate schedule is evaluated inside
jit so one compiled train_step serves the whole run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(1, warmup_steps))
        frac = jnp.clip((step - warmup_steps) /
                        max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------

def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Optimizer container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array],
                     Tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Schedule, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr(step)
        c1 = 1.0 - b1 ** stepf
        c2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments — the 1T-param MoE choice)
# ---------------------------------------------------------------------------

def adafactor(lr: Schedule, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Shazeer & Stern 2018, factored for params with ndim >= 2.

    State per >=2D leaf: row/col second-moment vectors over the two largest
    trailing dims — O(n+m) instead of O(n*m); the reason a 1T-parameter
    model's optimizer fits on a 512-chip slice at all (DESIGN.md §6).
    """
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"f": jax.tree.map(per_leaf, params)}

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr(step)
        beta = 1.0 - stepf ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., :, None] * vc[..., None, :]) / denom[..., None]
                upd_ = g / jnp.sqrt(vhat + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd_ = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-12)
            upd_ = upd_ / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * upd_).astype(p.dtype), new_s

        # grads' structure is a prefix of state["f"] (state subtrees hang
        # below each param leaf), so tree.map passes each state dict whole.
        out = jax.tree.map(upd, grads, state["f"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_state = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"f": new_state}

    return Optimizer("adafactor", init, update)


def make_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
