"""Greedy "few fit most" variant-set reduction (arXiv:2507.15277).

A tuned dispatch table knows, per data-shape bucket, the measured time of
every top-k candidate.  Shipping one bespoke variant per bucket is the
maximal-coverage answer; "A Few Fit Most" observes that a *handful* of
variants usually stays within a small tolerance of every bucket's best.
``compact_table`` computes that subset:

1. a *variant* is the pair ``(leaf_index, assignment)`` — the thing a build
   actually has to carry (one compiled Pallas specialization);
2. a variant **covers** a bucket when its measured time there is within
   ``(1 + tolerance)`` of the bucket's best measured time;
3. greedy set cover: repeatedly take the variant covering the most
   still-uncovered buckets (ties: lower total relative regret), until every
   coverable bucket is covered.

The result is recorded as the optional ``compaction`` section (advisory
only — dispatch keeps serving the full ranked list; the section tells a
deployment which kernel binaries it could prune and what that costs).
Buckets with no successful measurement are reported as uncovered rather
than silently dropped.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from .measure import MeasuredSample


def variant_key(leaf_index: int, assignment: Mapping[str, int]) -> str:
    asg = ",".join(f"{k}={int(v)}" for k, v in sorted(assignment.items()))
    return f"leaf{int(leaf_index)}|{asg}"


def compact_table(table: Mapping[str, Any],
                  samples: Sequence[MeasuredSample],
                  tolerance: float = 0.10) -> Dict[str, Any]:
    """Return a new payload with a ``compaction`` section appended.

    ``tolerance`` is relative: a variant covers a bucket when
    ``us <= (1 + tolerance) * best_us`` there.
    """
    # bucket -> {variant -> best measured us for that variant in the bucket}
    times: Dict[str, Dict[str, float]] = {}
    for s in samples:
        if s.us is None or s.us <= 0:
            continue
        v = variant_key(s.leaf_index, s.assignment)
        slot = times.setdefault(s.bucket, {})
        slot[v] = min(s.us, slot.get(v, float("inf")))

    best: Dict[str, float] = {b: min(vs.values()) for b, vs in times.items()}
    covers: Dict[str, Set[str]] = {}          # variant -> buckets it covers
    regret: Dict[str, Dict[str, float]] = {}  # variant -> bucket -> rel. regret
    for b, vs in times.items():
        for v, us in vs.items():
            r = us / best[b] - 1.0
            if r <= tolerance:
                covers.setdefault(v, set()).add(b)
                regret.setdefault(v, {})[b] = r

    selected: List[str] = []
    uncovered: Set[str] = set(times)
    steps: List[Dict[str, Any]] = []
    while uncovered:
        scored: List[Tuple[int, float, str]] = []
        for v, bs in covers.items():
            gain = bs & uncovered
            if gain:
                scored.append((len(gain),
                               sum(regret[v][b] for b in gain), v))
        if not scored:
            break                             # remaining buckets uncoverable
        # most new buckets first; ties broken by lower total regret
        scored.sort(key=lambda t: (-t[0], t[1], t[2]))
        _, _, pick = scored[0]
        newly = sorted(covers[pick] & uncovered)
        uncovered -= covers[pick]
        selected.append(pick)
        steps.append({"variant": pick, "new_buckets": newly})

    # accounting runs over *every* non-empty bucket of the table, so a
    # bucket whose measurements all failed shows up as uncovered instead of
    # silently shrinking the denominator
    all_buckets = sorted({b for b, es in table.get("buckets", {}).items()
                          if es} | set(times))
    all_variants = sorted({v for vs in times.values() for v in vs})
    per_bucket: Dict[str, Any] = {}
    for b in all_buckets:
        options = [(regret[v][b], v) for v in selected
                   if b in covers.get(v, ())]
        if options:
            r, v = min(options)
            per_bucket[b] = {"variant": v, "regret": round(r, 4)}
        else:
            per_bucket[b] = None              # unmeasured or over-tolerance

    out = dict(table)
    out["compaction"] = {
        "tolerance": tolerance,
        "variants": selected,
        "steps": steps,
        "total_variants_measured": len(all_variants),
        "buckets_total": len(all_buckets),
        "buckets_covered": len(times) - len(uncovered),
        "per_bucket": per_bucket,
    }
    return out


def compaction_summary(table: Mapping[str, Any]) -> Optional[str]:
    """One-line human summary of a table's compaction section (or None)."""
    c = table.get("compaction")
    if not isinstance(c, dict):
        return None
    return (f"{c.get('total_variants_measured', '?')} measured variants -> "
            f"{len(c.get('variants', []))} selected; "
            f"{c.get('buckets_covered', 0)}/{c.get('buckets_total', 0)} "
            f"buckets within {c.get('tolerance')} of best")
