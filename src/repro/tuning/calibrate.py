"""KLARAPTOR-style least-squares calibration of the symbolic ranking.

The offline model scores a candidate with the performance-measure rationals
(occupancy, MXU utilization, ... — paper §3.3) evaluated symbolically; this
module fits, per family, how much each measure actually *costs* on the
measured device.  Following KLARAPTOR's rational-program calibration
(arXiv:1911.02373) the model is multiplicative, hence linear in log space:

    log t  =  c0  +  c_w · log(work)  +  Σ_i c_i · log(1 / v_i)

where ``v_i ∈ (0, 1]`` is performance measure *i* for the candidate and
``work`` is the product of the bucket's data dims.  Ordinary least squares
over every measured sample of the family yields the scale coefficients
``c`` — the per-device "exponents" the symbolic model guessed at.

``calibrate_table`` then rewrites a dispatch table's per-bucket candidate
order: measured candidates sort by measured time; candidates whose
measurement failed (or was skipped) are slotted in by *model-predicted*
time when a fit exists, and keep their symbolic rank otherwise.  The result
lands in two optional FORMAT_VERSION-2 sections:

  ``calibration``     — fit coefficients + residual/agreement diagnostics,
  ``measured_ranks``  — per bucket: the re-ranked entry order + raw times.

Both sections are advisory: dispatch falls back to the symbolic ranking on
any malformed content, and feasibility still comes solely from the
constraint tree.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.counters import CounterKind
from ..core.plan import FamilySpec, KernelPlan, Leaf
from .measure import MeasuredSample

_EPS = 1e-12                      # floor for measures before taking logs


def _perf_counter_names(family: FamilySpec) -> List[str]:
    return [c.name for c in family.counters()
            if c.kind is CounterKind.PERFORMANCE]


def _measure_values(family: FamilySpec, plan: KernelPlan,
                    values: Mapping[str, int]) -> Optional[List[float]]:
    """Evaluate every performance measure at a full binding; None if any
    symbol stays unbound (sample is then dropped from the fit)."""
    out = []
    for c in family.counters():
        if c.kind is not CounterKind.PERFORMANCE:
            continue
        num, den = c.evaluate(family, plan)
        try:
            n, d = float(num.eval(values)), float(den.eval(values))
        except KeyError:
            return None
        if d <= 0:
            return None
        out.append(min(1.0, max(_EPS, n / d)))
    return out


def _features(measures: Sequence[float], work: float) -> List[float]:
    return ([1.0, math.log(max(work, 1.0))]
            + [math.log(1.0 / m) for m in measures])


@dataclass
class CalibrationFit:
    """Per-family least-squares fit of measured time vs symbolic measures."""

    family: str
    feature_names: List[str]
    coeffs: List[float]
    n_samples: int
    rms_log_residual: float
    top1_agreement: float = float("nan")   # filled by calibrate_table
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self) -> Dict[str, Any]:
        return {
            "method": "log-lstsq",
            "family": self.family,
            "features": list(self.feature_names),
            "coeffs": [float(c) for c in self.coeffs],
            "n_samples": int(self.n_samples),
            "rms_log_residual": float(self.rms_log_residual),
            "top1_agreement": (None if math.isnan(self.top1_agreement)
                               else float(self.top1_agreement)),
            "meta": dict(self.meta),
        }

    @classmethod
    def from_obj(cls, obj: Mapping[str, Any]) -> "CalibrationFit":
        agree = obj.get("top1_agreement")
        return cls(family=str(obj["family"]),
                   feature_names=[str(f) for f in obj["features"]],
                   coeffs=[float(c) for c in obj["coeffs"]],
                   n_samples=int(obj["n_samples"]),
                   rms_log_residual=float(obj["rms_log_residual"]),
                   top1_agreement=float("nan") if agree is None else agree,
                   meta=dict(obj.get("meta", {})))


def _sample_row(family: FamilySpec, plan: KernelPlan, s: MeasuredSample,
                bindings: Mapping[str, int]) -> Optional[List[float]]:
    values = {**bindings, **s.data, **s.assignment}
    measures = _measure_values(family, plan, values)
    if measures is None:
        return None
    work = float(np.prod([float(v) for v in s.data.values()]))
    return _features(measures, work)


def fit_family(family: FamilySpec, table: Mapping[str, Any],
               samples: Sequence[MeasuredSample],
               meta: Optional[Mapping[str, Any]] = None,
               leaves: Optional[Mapping[int, Leaf]] = None
               ) -> Optional[CalibrationFit]:
    """OLS in log space over all successfully measured samples.

    Returns ``None`` when fewer samples than features survived — the table
    then ships measured ranks without a model (symbolic order covers the
    unmeasured tail).  ``leaves`` lets a caller that already parsed the
    table's leaf section (``serde.table_leaves``) avoid re-parsing it.
    """
    from ..artifacts import serde
    bindings = table.get("machine_bindings", {})
    if leaves is None:
        leaves = serde.table_leaves(table)
    names = (["intercept", "log_work"]
             + [f"log_inv_{n}" for n in _perf_counter_names(family)])
    rows, ys = [], []
    for s in samples:
        if s.us is None or s.us <= 0:
            continue
        leaf = leaves.get(s.leaf_index)
        if leaf is None:
            continue
        row = _sample_row(family, leaf.plan, s, bindings)
        if row is None:
            continue
        rows.append(row)
        ys.append(math.log(s.us))
    if len(rows) < len(names):
        return None
    X = np.asarray(rows, dtype=np.float64)
    y = np.asarray(ys, dtype=np.float64)
    coeffs, *_ = np.linalg.lstsq(X, y, rcond=None)
    resid = y - X @ coeffs
    return CalibrationFit(
        family=family.name, feature_names=names,
        coeffs=[float(c) for c in coeffs], n_samples=len(rows),
        rms_log_residual=float(np.sqrt(np.mean(resid ** 2))),
        meta=dict(meta or {}))


def predict_us(fit: CalibrationFit, family: FamilySpec, plan: KernelPlan,
               assignment: Mapping[str, int], data: Mapping[str, int],
               bindings: Mapping[str, int]) -> Optional[float]:
    """Model-predicted microseconds for one candidate (None if unbindable)."""
    values = {**bindings, **data, **assignment}
    measures = _measure_values(family, plan, values)
    if measures is None:
        return None
    work = float(np.prod([float(v) for v in data.values()]))
    x = _features(measures, work)
    if len(x) != len(fit.coeffs):
        return None
    return float(math.exp(float(np.dot(x, fit.coeffs))))


def calibrate_table(family: FamilySpec, table: Mapping[str, Any],
                    samples: Sequence[MeasuredSample],
                    fit: Optional[CalibrationFit] = None,
                    meta: Optional[Mapping[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Return a new dispatch-table payload with ``calibration`` +
    ``measured_ranks`` sections; the symbolic ``buckets`` stay untouched.

    Ranking per bucket is tiered — measurement is authoritative, the model
    only orders the tail: (1) measured entries ascending by measured time,
    (2) unmeasured entries ascending by model-predicted time when ``fit``
    is available, (3) the rest in symbolic order.  A candidate the machine
    was never asked to run can therefore never outrank one it was.
    ``top1_agreement`` records, over buckets with
    at least two measured candidates, how often the model's fastest pick
    matches the measured fastest — the diagnostic that says whether the
    symbolic polynomials (as calibrated) describe this machine at all.
    """
    from ..artifacts import serde
    leaves = serde.table_leaves(table)
    if fit is None:
        fit = fit_family(family, table, samples, meta=meta, leaves=leaves)
    bindings = table.get("machine_bindings", {})
    by_bucket: Dict[str, List[MeasuredSample]] = {}
    for s in samples:
        by_bucket.setdefault(s.bucket, []).append(s)

    measured_ranks: Dict[str, Any] = {}
    agree_hits = agree_total = 0
    for bucket, bucket_samples in sorted(by_bucket.items()):
        entries = table.get("buckets", {}).get(bucket, [])
        us_by_pos: Dict[int, Optional[float]] = {
            s.entry_index: s.us for s in bucket_samples}
        if not any(us is not None for us in us_by_pos.values()):
            # no successful measurement in this bucket: emitting an order
            # would let dispatch report "measured" for what is really the
            # symbolic (or model-only) ranking — leave the bucket untuned
            continue
        keyed: List[Any] = []                 # (tier, time-or-pos, pos)
        pred_by_pos: Dict[int, float] = {}
        for pos, entry in enumerate(entries):
            us = us_by_pos.get(pos)
            if us is not None:
                keyed.append((0, us, pos))    # tier 1: measured
                continue
            if fit is not None:
                leaf = leaves.get(int(entry.get("leaf_index", -1)))
                s0 = bucket_samples[0]
                if leaf is not None:
                    asg = {k: int(v) for k, v in entry["assignment"].items()}
                    p = predict_us(fit, family, leaf.plan, asg, s0.data,
                                   bindings)
                    if p is not None:
                        pred_by_pos[pos] = p
                        keyed.append((1, p, pos))   # tier 2: model-predicted
                        continue
            keyed.append((2, pos, pos))       # tier 3: symbolic order
        keyed.sort(key=lambda k: (k[0], k[1], k[-1]))
        order = [k[-1] for k in keyed]
        measured_ranks[bucket] = {
            "order": order,
            "us": [None if us_by_pos.get(p) is None
                   else round(float(us_by_pos[p]), 3)
                   for p in range(len(entries))],
            "predicted_us": {str(p): round(v, 3)
                             for p, v in sorted(pred_by_pos.items())},
        }
        measured = {p: u for p, u in us_by_pos.items() if u is not None}
        if fit is not None and len(measured) >= 2:
            agree_total += 1
            best_measured = min(measured, key=measured.__getitem__)
            preds = {}
            for pos in measured:
                entry = entries[pos]
                leaf = leaves.get(int(entry["leaf_index"]))
                if leaf is None:
                    continue
                asg = {k: int(v) for k, v in entry["assignment"].items()}
                p = predict_us(fit, family, leaf.plan, asg,
                               bucket_samples[0].data, bindings)
                if p is not None:
                    preds[pos] = p
            if preds and min(preds, key=preds.__getitem__) == best_measured:
                agree_hits += 1

    out = dict(table)
    out["format"] = serde.FORMAT_VERSION
    out["measured_ranks"] = measured_ranks
    if fit is not None:
        if agree_total:
            fit.top1_agreement = agree_hits / agree_total
        out["calibration"] = fit.to_obj()
    return out
