"""Measurement-calibrated dispatch (closing the offline loop on hardware).

The case discussion ranks kernel variants with a purely *symbolic*
performance model (paper §4); nothing in the offline pipeline ever checks
that ranking against the machine it claims to describe.  This package adds
the missing feedback edge, following KLARAPTOR (arXiv:1911.02373 — fit the
rational performance model to measured timings per device) and "A Few Fit
Most" (arXiv:2507.15277 — a handful of calibrated variants covers most
shapes):

- :mod:`repro.tuning.measure`   — time the top-k pre-ranked candidates of a
  dispatch table per ``(family, machine, bucket)`` on real or interpreted
  Pallas (deterministic seeds, trimmed mean over repeats);
- :mod:`repro.tuning.calibrate` — least-squares fit of per-family scale
  coefficients for the symbolic performance-measure rationals, then re-rank
  every bucket by measured (or model-predicted) time;
- :mod:`repro.tuning.compact`   — greedy "few fit most" reduction: the
  smallest variant subset whose measured time stays within a tolerance of
  each bucket's best.

``scripts/tune_artifacts.py`` drives measure → calibrate → compact and
rewrites the dispatch table in place (``FORMAT_VERSION`` 2: the sections are
*optional*, and per the artifact policy a v1 reader treats the new table as
a cache miss, never an error).  :mod:`repro.artifacts.dispatch` prefers the
measured order when a bucket carries one and falls back to the symbolic
ranking otherwise — serving behaviour is unchanged for untuned tables.

Invariants (shared with :mod:`repro.artifacts.serde`):

- tuned tables remain canonical-bytes deterministic: re-serializing a
  reloaded tuned table reproduces it byte for byte;
- measurement can only *reorder* a bucket's candidate list, never add to
  it — feasibility always comes from the constraint tree, so a tuned table
  is exactly as sound as the symbolic one;
- every reader of the new sections degrades to the symbolic ranking on any
  malformed content (cache-miss-never-error).
"""
from .calibrate import CalibrationFit, calibrate_table, fit_family
from .compact import compact_table
from .measure import MeasureConfig, MeasuredSample, measure_table, \
    parse_bucket_key

__all__ = [
    "CalibrationFit", "MeasureConfig", "MeasuredSample", "calibrate_table",
    "compact_table", "fit_family", "measure_table", "parse_bucket_key",
]
