"""Timing harness for dispatch-table candidates (the measurement half of
KLARAPTOR-style calibration).

Given a compiled dispatch table (:mod:`repro.artifacts.compile`), this
module re-runs the top-k pre-ranked candidates of every data-shape bucket as
*actual kernels* — ``family.instantiate(plan, assignment)`` under ``jax``,
with ``interpret=True`` on hosts without a TPU so the same harness runs on
the CPU CI container — and records a trimmed-mean wall time per candidate.

Invariants:

- **deterministic inputs** — operand tensors are derived from a PRNG key
  seeded by ``(family, bucket, cfg.seed)``, so two runs time identical work;
- **measurement never invents candidates** — only entries already present
  in the table (hence already feasibility-checked offline) are timed;
- **failure is data, not an error** — a candidate that fails to instantiate
  or run records ``us=None`` and keeps its symbolic rank; the sweep
  continues (the cache-miss-never-error policy, applied to measurement).

Interpreted-Pallas timings are *relative* quality signals (the paper's
case-discussion experiments use the same reasoning): they order variants by
executed work on this host, they are not TPU microseconds.  The calibration
layer treats them as an opaque monotone cost, so swapping in a real-TPU
timer changes numbers, not code paths.
"""
from __future__ import annotations

import re
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import numpy as np

from ..core.plan import FamilySpec, KernelPlan

_BUCKET_PART = re.compile(r"^([A-Za-z_]+?)(\d+)$")


def parse_bucket_key(key: str) -> Dict[str, int]:
    """Inverse of :func:`repro.artifacts.dispatch.bucket_key`.

    Relies on the repo-wide convention that data-parameter names contain no
    trailing digits (``M``, ``N``, ``K``, ``SQ``, ``HD``, ``STATE``); the
    bucket grammar is ``<name><pow2>`` joined by ``|``.
    """
    out: Dict[str, int] = {}
    for part in key.split("|"):
        m = _BUCKET_PART.match(part)
        if m is None:
            raise ValueError(f"unparseable bucket part {part!r} in {key!r}")
        out[m.group(1)] = int(m.group(2))
    return out


def clamp_data(data: Mapping[str, int], max_dim: int) -> Dict[str, int]:
    """Clamp each dim to ``max_dim`` (keeps powers of two powers of two)."""
    return {k: min(int(v), max_dim) for k, v in data.items()}


# Per family: the smallest data dims at which a set of candidate assignments
# runs without padding, i.e. every block extent fits inside its data dim.
# Measuring below these floors would rank candidates by *padding overhead*
# that does not exist at the bucket's true shape.
def _block_minima(family_name: str,
                  assignments: Sequence[Mapping[str, int]]
                  ) -> Dict[str, int]:
    req: Dict[str, int] = {}

    def need(dim: str, value: int) -> None:
        req[dim] = max(req.get(dim, 1), int(value))

    for a in assignments:
        if family_name == "matmul":
            need("M", a["bm"]); need("K", a["bk"]); need("N", a["bn"] * a["s"])
        elif family_name in ("matadd", "transpose"):
            need("M", a["bm"]); need("N", a["bn"] * a["s"])
        elif family_name == "jacobi1d":
            need("N", a["B"] * a["s"] + 2)
        elif family_name == "flash_attention":
            need("SQ", max(a["bq"], a["bkv"]))
        elif family_name == "ssd_scan":
            need("SQ", a["chunk"])
    return req


def measure_shape(family_name: str, data: Mapping[str, int],
                  assignments: Sequence[Mapping[str, int]],
                  max_dim: int) -> Dict[str, int]:
    """The shape a bucket is measured at: dims clamped to ``max_dim``, but
    never below the block extents of the candidates being compared.

    Interpreted Pallas pays per grid step on the host CPU, so measuring a
    4096^3 matmul bucket verbatim is infeasible.  A naive clamp, though,
    can shrink a dim *below* a candidate's block size — the kernel then
    pads, and the measured order reflects padding waste the true bucket
    shape never pays.  Flooring each dim at the candidates' block minima
    keeps every candidate in its real blocking regime, so the relative
    order transfers; a bucket whose true dims are already below a block
    extent is measured verbatim (padding there is what serving would pay).
    Real-TPU timer runs can set ``max_dim`` high enough to make this a
    no-op.
    """
    req = _block_minima(family_name, assignments)
    return {k: min(int(v), max(max_dim, req.get(k, 1)))
            for k, v in data.items()}


@dataclass(frozen=True)
class MeasureConfig:
    iters: int = 3          # timed repeats per candidate
    warmup: int = 1         # untimed runs (jit/interpreter warm-up)
    trim: int = 1           # repeats dropped from each end before the mean
    max_dim: int = 256      # clamp_data bound for measured shapes
    top_k: int = 8          # candidates measured per bucket (prefix of table)
    seed: int = 0           # base PRNG seed (mixed with family+bucket)
    interpret: bool = True  # interpreted Pallas (CPU hosts); False on TPU


@dataclass
class MeasuredSample:
    """One (bucket, candidate) timing — the unit calibrate/compact consume."""

    bucket: str
    entry_index: int                  # position in the bucket's symbolic list
    leaf_index: int
    assignment: Dict[str, int]
    score: float                      # symbolic model score (from the table)
    data: Dict[str, int]              # the (possibly clamped) measured shape
    us: Optional[float]               # trimmed-mean microseconds; None=failed
    repeats: List[float] = field(default_factory=list)


def _seed_for(family_name: str, bucket: str, base: int) -> int:
    return zlib.crc32(f"{family_name}|{bucket}|{base}".encode()) & 0x7FFFFFFF


def _build_inputs(family_name: str, data: Mapping[str, int], seed: int
                  ) -> Tuple[Tuple[Any, ...], Dict[str, Any]]:
    """Deterministic operand tensors for one family at one data shape."""
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(seed)

    def normal(k, shape, dtype=jnp.float32):
        return jax.random.normal(k, shape, dtype)

    if family_name == "matmul":
        k1, k2 = jax.random.split(key)
        M, N, K = data["M"], data["N"], data["K"]
        return (normal(k1, (M, K), jnp.bfloat16),
                normal(k2, (K, N), jnp.bfloat16)), {}
    if family_name == "matadd":
        k1, k2 = jax.random.split(key)
        M, N = data["M"], data["N"]
        return (normal(k1, (M, N)), normal(k2, (M, N))), {}
    if family_name == "transpose":
        return (normal(key, (data["M"], data["N"])),), {}
    if family_name == "jacobi1d":
        return (normal(key, (data["N"],)), 4), {}
    if family_name == "flash_attention":
        k1, k2, k3 = jax.random.split(key, 3)
        sq, hd = data["SQ"], data["HD"]
        shape = (1, sq, hd)
        return (normal(k1, shape, jnp.bfloat16),
                normal(k2, shape, jnp.bfloat16),
                normal(k3, shape, jnp.bfloat16)), {"causal": True}
    if family_name == "ssd_scan":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        sq, hd, st = data["SQ"], data["HD"], data["STATE"]
        heads = 1
        a = jax.nn.sigmoid(normal(k2, (sq, heads)))       # decay in (0, 1)
        return (normal(k1, (sq, heads, hd)), a,
                normal(k3, (sq, heads, st)),
                normal(k4, (sq, heads, st))), {}
    raise KeyError(f"no input builder for family {family_name!r}")


def default_timer(family: FamilySpec, plan: KernelPlan,
                  assignment: Mapping[str, int], data: Mapping[str, int],
                  cfg: MeasureConfig) -> List[float]:
    """Run the candidate kernel; return per-repeat wall times in seconds.

    Raises on instantiation/execution failure — ``measure_table`` converts
    that into a ``us=None`` sample.
    """
    import time

    import jax
    fn = family.instantiate(plan, dict(assignment), interpret=cfg.interpret)
    seed = _seed_for(family.name, repr(sorted(data.items())), cfg.seed)
    args, kwargs = _build_inputs(family.name, data, seed)
    for _ in range(max(0, cfg.warmup)):
        jax.block_until_ready(fn(*args, **kwargs))
    out = []
    for _ in range(max(1, cfg.iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        out.append(time.perf_counter() - t0)
    return out


def trimmed_mean_us(repeats: Sequence[float], trim: int) -> float:
    """Trimmed mean (seconds -> microseconds); robust to scheduler noise."""
    xs = sorted(float(r) for r in repeats)
    if trim > 0 and len(xs) > 2 * trim:
        xs = xs[trim:-trim]
    return float(np.mean(xs) * 1e6)


Timer = Callable[[FamilySpec, KernelPlan, Mapping[str, int],
                  Mapping[str, int], MeasureConfig], List[float]]


def measure_table(family: FamilySpec, table: Mapping[str, Any],
                  cfg: MeasureConfig = MeasureConfig(),
                  timer: Optional[Timer] = None,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> List[MeasuredSample]:
    """Time the top-``cfg.top_k`` candidates of every bucket in ``table``.

    ``timer`` is injectable (tests use a deterministic fake; a TPU host can
    supply a non-interpreted one); the default runs
    real/interpreted Pallas via :func:`default_timer`.
    """
    from ..artifacts import serde
    timer = timer or default_timer
    samples: List[MeasuredSample] = []
    leaves = serde.table_leaves(table)
    for bucket in sorted(table.get("buckets", {})):
        entries = table["buckets"][bucket]
        measured_entries = entries[:cfg.top_k]
        try:
            data = measure_shape(
                family.name, parse_bucket_key(bucket),
                [{k: int(v) for k, v in e["assignment"].items()}
                 for e in measured_entries], cfg.max_dim)
        except (KeyError, TypeError, ValueError):
            continue                          # unparseable bucket: skip
        for pos, entry in enumerate(measured_entries):
            leaf = leaves.get(int(entry["leaf_index"]))
            if leaf is None:
                continue
            asg = {k: int(v) for k, v in entry["assignment"].items()}
            if progress:
                progress(f"{family.name}/{bucket}#{pos} {asg}")
            try:
                repeats = timer(family, leaf.plan, asg, data, cfg)
                us: Optional[float] = trimmed_mean_us(repeats, cfg.trim)
            except Exception:                 # noqa: BLE001 — failure is data
                repeats, us = [], None
            samples.append(MeasuredSample(
                bucket=bucket, entry_index=pos,
                leaf_index=int(entry["leaf_index"]), assignment=asg,
                score=float(entry["score"]), data=dict(data), us=us,
                repeats=[float(r) for r in repeats]))
    return samples
