"""Fault-tolerant checkpointing: atomic, checksummed, async, multi-shard.

Layout on disk::

    <dir>/step_000123/
        MANIFEST.json        {step, leaves: {name: {shape,dtype,crc32,file}}}
        <leaf>.npy           one file per pytree leaf (host-local shard)
    <dir>/LATEST             text file naming the newest *complete* step dir

Guarantees:

* **Atomicity** — a step directory is written under ``.tmp_step_*`` and
  renamed into place only after every leaf and the manifest are fsynced;
  ``LATEST`` is updated last.  A crash mid-save never corrupts the previous
  checkpoint.
* **Integrity** — every leaf carries a CRC32; ``restore`` verifies and falls
  back to the previous step directory on mismatch (bit-rot / partial write).
* **Async** — ``save_async`` snapshots to host RAM (device_get) synchronously
  then writes on a background thread, double-buffered so at most one save is
  in flight; the train loop blocks only if it laps the writer.
* **Multi-host** — each host writes only the leaves (shards) it owns under a
  ``host<k>`` suffix; restore concatenates per-host shards.  On this
  single-process container host_count == 1, but the format carries the field
  so real multi-host restores are format-compatible.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "::"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        out[name] = np.asarray(leaf)
    return out


def _unflatten_like(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = flat[name]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != "
                f"expected {want.shape}")
        leaves.append(arr.astype(want.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_index: int = 0,
                 host_count: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_index = host_index
        self.host_count = host_count
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}_h{self.host_index}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "host_index": self.host_index,
                    "host_count": self.host_count, "leaves": {}}
        for name, arr in flat.items():
            safe = name.replace("/", "_")
            fn = f"{safe}.h{self.host_index}.npy"
            path = os.path.join(tmp, fn)
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][name] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()), "file": fn,
            }
        mpath = os.path.join(tmp, f"MANIFEST.h{self.host_index}.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # single-host: rename into place; multi-host would barrier here
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def save(self, step: int, tree: PyTree) -> None:
        """Synchronous save (used at job end and by tests)."""
        self.wait()
        self._write(step, _flatten(jax.device_get(tree)))

    def save_async(self, step: int, tree: PyTree) -> None:
        """Snapshot now, write in background (double-buffered)."""
        self.wait()                      # at most one save in flight
        flat = _flatten(jax.device_get(tree))

        def run():
            try:
                self._write(step, flat)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore ----------------------------------------------------------------
    def available_steps(self):
        return sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                      if d.startswith("step_"))

    def _load_step(self, step: int, template: PyTree) -> PyTree:
        d = os.path.join(self.dir, f"step_{step:09d}")
        mpath = os.path.join(d, f"MANIFEST.h{self.host_index}.json")
        with open(mpath) as f:
            manifest = json.load(f)
        flat = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if zlib.crc32(arr.tobytes()) != meta["crc32"]:
                raise IOError(f"crc mismatch for {name} at step {step}")
            flat[name] = arr
        return _unflatten_like(template, flat)

    def restore_latest(self, template: PyTree
                       ) -> Tuple[Optional[int], Optional[PyTree]]:
        """Restore the newest valid checkpoint; fall back past corrupt ones."""
        self.wait()
        for step in reversed(self.available_steps()):
            try:
                return step, self._load_step(step, template)
            except BaseException:
                continue            # corrupt / partial — try the previous one
        return None, None
