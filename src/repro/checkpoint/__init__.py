"""Atomic, checksummed, async checkpointing."""
from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
