"""Llama-3-8B — dense GQA, 128k vocab [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    layers=32, d_model=4096, heads=32, kv_heads=8, d_ff=14336, vocab=128256,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=192, vocab=512,
)
