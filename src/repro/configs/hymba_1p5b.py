"""Hymba-1.5B — hybrid parallel attention + Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hybrid block: attention and SSD paths run in parallel on the same input and
their outputs are summed (the paper's "parallel heads").  Sliding-window
attention (1k) keeps the attention path sub-quadratic for long_500k.
"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    layers=32, d_model=1600, heads=25, kv_heads=5, d_ff=5504, vocab=32001,
    head_dim=64,
    block="hybrid",
    ssm=SSMConfig(state=16, heads=25, head_dim=64, chunk=128),
    window=1024,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=128, vocab=256,
    head_dim=16,
    block="hybrid",
    ssm=SSMConfig(state=8, heads=4, head_dim=16, chunk=16),
    window=32,
    subquadratic=True,
)
