"""Whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.  The conv frontend is a
STUB per the task spec: ``input_specs()`` supplies precomputed 1500-frame
encoder embeddings; the transformer backbone (32 enc + 32 dec layers with
cross-attention) is fully implemented.
"""
from ..models.config import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    layers=32, d_model=1280, heads=20, kv_heads=20, d_ff=5120, vocab=51866,
    encoder=EncoderConfig(layers=32, seq_len=1500),
    frontend="stub",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    layers=2, d_model=64, heads=4, kv_heads=4, d_ff=128, vocab=256,
    encoder=EncoderConfig(layers=2, seq_len=32),
    frontend="stub",
)
