"""Granite-3 8B — dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    layers=40, d_model=4096, heads=32, kv_heads=8, d_ff=12800, vocab=49155,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=160, vocab=256,
)
