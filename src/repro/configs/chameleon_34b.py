"""Chameleon-34B — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early fusion means
image patches arrive as discrete VQ tokens in the same vocabulary — the VQ
tokenizer is the stubbed frontend; the backbone is a standard dense LM.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    layers=48, d_model=8192, heads=64, kv_heads=8, d_ff=22016, vocab=65536,
    frontend="stub",
    remat="full",
)

SMOKE = ModelConfig(
    name="chameleon-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=192, vocab=256,
    frontend="stub",
)
