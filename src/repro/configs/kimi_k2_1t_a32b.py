"""Kimi-K2 — trillion-parameter MoE (paper-table config) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8.  head_dim=128 (decoupled from d_model/heads).
Optimizer: adafactor — full-Adam states for 1T params do not fit 512x16GB;
this is a deliberate production decision recorded in DESIGN.md.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    layers=61, d_model=7168, heads=64, kv_heads=8, d_ff=2048, vocab=163840,
    head_dim=128,
    block="attn_moe",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048),
    optimizer="adafactor",
    remat="full",
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="kimi-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=96, vocab=256,
    head_dim=16,
    block="attn_moe",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96),
)
