"""Qwen1.5-4B — MHA with QKV bias, 152k vocab [hf:Qwen/Qwen1.5-0.5B; hf].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    layers=40, d_model=2560, heads=20, kv_heads=20, d_ff=6912, vocab=151936,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke",
    layers=2, d_model=64, heads=4, kv_heads=4, d_ff=128, vocab=512,
    qkv_bias=True,
)
