"""Llama-4-Scout 17B-A16E — MoE top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, MoE 16e top-1.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    layers=48, d_model=5120, heads=40, kv_heads=8, d_ff=8192, vocab=202048,
    head_dim=128,
    block="attn_moe",
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192),
    remat="full",
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=128, vocab=256,
    head_dim=16,
    block="attn_moe",
    moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128),
)
