"""Mamba2-130M — attention-free SSD [arXiv:2405.21060].

24L d_model=768 vocab=50280, ssm_state=128.  expand=2 -> d_inner=1536,
head_dim=64 -> 24 SSD heads.  The paper's attention-blocking technique is
inapplicable (no attention); the comprehensive tree instead drives the SSD
chunk kernel (DESIGN.md §7).
"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    layers=24, d_model=768, heads=12, kv_heads=12, d_ff=0, vocab=50280,
    block="ssm",
    ssm=SSMConfig(state=128, heads=24, head_dim=64, chunk=128),
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    layers=2, d_model=64, heads=4, kv_heads=4, d_ff=0, vocab=256,
    block="ssm",
    ssm=SSMConfig(state=16, heads=4, head_dim=16, chunk=16),
    subquadratic=True,
)
