"""Yi-6B — llama-architecture dense GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    layers=32, d_model=4096, heads=32, kv_heads=4, d_ff=11008, vocab=64000,
)

SMOKE = ModelConfig(
    name="yi-smoke",
    layers=2, d_model=64, heads=4, kv_heads=2, d_ff=160, vocab=256,
)
