"""Assigned architecture registry: one module per arch (``--arch <id>``).

Each module exports ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family configuration for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCH_IDS = (
    "hymba_1p5b",
    "yi_6b",
    "llama3_8b",
    "qwen1p5_4b",
    "granite_3_8b",
    "whisper_large_v3",
    "kimi_k2_1t_a32b",
    "llama4_scout_17b_a16e",
    "chameleon_34b",
    "mamba2_130m",
)

# canonical external ids (task spec) -> module names
ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "yi-6b": "yi_6b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-4b": "qwen1p5_4b",
    "granite-3-8b": "granite_3_8b",
    "whisper-large-v3": "whisper_large_v3",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "chameleon-34b": "chameleon_34b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
