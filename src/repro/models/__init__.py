"""LM substrate: configs, functional layers, and full-model assembly."""
from .config import (EncoderConfig, ModelConfig, MoEConfig, SSMConfig,
                     SHAPES, SHAPES_BY_NAME, ShapeConfig)
from .transformer import (block_apply, cache_spec_axes, decode_step, encode,
                          forward, init_cache, init_layer, init_model,
                          init_paged_cache, paged_copy_block,
                          paged_decode_step, paged_prefill_chunk,
                          param_count, prefill)

__all__ = [
    "EncoderConfig", "ModelConfig", "MoEConfig", "SSMConfig", "SHAPES",
    "SHAPES_BY_NAME", "ShapeConfig", "block_apply", "cache_spec_axes",
    "decode_step", "encode", "forward", "init_cache", "init_layer",
    "init_model", "init_paged_cache", "paged_copy_block",
    "paged_decode_step", "paged_prefill_chunk", "param_count", "prefill",
]
