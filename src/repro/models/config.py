"""Model / run configuration for every assigned architecture."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    state: int
    heads: int            # SSD heads (d_model // head_dim)
    head_dim: int
    chunk: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    layers: int
    seq_len: int          # fixed frontend frames (whisper: 1500)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None           # default d_model // heads
    block: str = "attn_mlp"                  # attn_mlp | attn_moe | ssm | hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None  # enc-dec (whisper)
    window: Optional[int] = None             # sliding-window attention (hybrid)
    qkv_bias: bool = False                   # qwen-style
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # storage dtype of >=2D weights; "bfloat16" for the 1T MoE, where f32
    # masters cannot fit on 512x16GB (adafactor updates in f32 internally)
    param_dtype: str = "float32"
    # beyond-paper perf toggles (EXPERIMENTS.md §Perf); empty = the
    # paper-faithful baseline.  Known flags:
    #   attn_q_heads   — GQA computes on repeated query heads so the head
    #                    dim shards by nh (divisible by 16) instead of nk
    #   rope_compute   — rope cos/sin in compute dtype (bf16) not f32
    #   probs_bf16     — attention probabilities cast to compute dtype
    #                    after the f32 softmax, before the PV matmul
    perf_flags: Tuple[str, ...] = ()
    # long-context policy: "linear" archs may run the 500k decode cell
    subquadratic: bool = False
    # modality frontend: "none" (token ids) | "stub" (precomputed embeddings)
    frontend: str = "none"
    optimizer: str = "adamw"                 # adamw | adafactor (1T-scale)
    remat: str = "none"                      # none | full | dots

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.heads)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests (same family, tiny dims)."""
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.layers
        hd, nh, nk = self.hd, self.heads, self.kv_heads
        attn = d * nh * hd + 2 * d * nk * hd + nh * hd * d
        mlp = 3 * d * f                                       # SwiGLU
        if self.block in ("attn_moe",) and self.moe:
            mlp = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        ssm = 0
        if self.ssm is not None:
            s = self.ssm
            proj = d * s.heads * s.head_dim                   # x proj
            bc = 2 * d * s.state                              # B, C (shared)
            out = s.heads * s.head_dim * d
            ssm = proj + bc + out + d * s.heads + s.heads     # + decay proj
        per_layer = {
            "attn_mlp": attn + mlp,
            "attn_moe": attn + mlp,
            "ssm": ssm + 3 * d * f if f else ssm,
            "hybrid": attn + ssm + mlp,
        }[self.block]
        emb = v * d * 2                                       # in + out (untied)
        enc = 0
        if self.encoder is not None:
            enc = self.encoder.layers * (attn + 3 * d * f + attn)  # + cross
        return emb + L * per_layer + enc

    def active_param_count(self) -> int:
        """MoE: only routed experts count toward per-token FLOPs."""
        if self.block != "attn_moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.layers
        dense = self.param_count() - L * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        return dense + L * self.moe.top_k * 3 * d * self.moe.d_ff_expert


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
