"""Mixture-of-Experts layer (GShard-style grouped dispatch, EP-shardable).

Routing: softmax top-k with capacity dropping.  Tokens are processed in
*groups* so the dispatch/combine one-hot tensors stay small and the group
axis shards over the data mesh axis while the expert axis shards over the
model mesh axis (EP).  GSPMD then emits the all-to-all between the
token-sharded and expert-sharded layouts — the paper's "collective schedule"
falls out of the sharding annotations rather than hand-written NCCL.

Shapes (per call):
  x          (B, S, d)      -> tokens (G, gsz, d)
  router     (d, E)
  wi, wg     (E, d, f)      SwiGLU expert FFN
  wo         (E, f, d)
  dispatch   (G, gsz, E, C) combine weights; C = ceil(gsz*k*cf/E)

The auxiliary load-balance loss (Switch-style) is returned so the training
loop can add it to the objective.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


def _norm_init(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) > 1 else 1
    return jax.random.normal(key, shape, dtype) / max(1, fan_in) ** 0.5


# production mesh device counts the a2a layout must divide into
_A2A_PAD_TO = 512

#: Default token-group size for the grouped dispatch.  Shared with
#: repro.plans.trace, which derives the capacity-width expert-matmul shapes
#: a config's serve path will dispatch — keep them from drifting apart.
MOE_GROUP_SIZE = 1024


def a2a_padded_experts(cfg: ModelConfig) -> int:
    """Stored expert count under the 'moe_a2a' flag.

    The all-to-all schedule distributes experts over every device, so
    storage pads E up to a multiple of the largest production mesh (512;
    256 divides it).  Only worthwhile when E is already device-scale —
    small-E archs (llama4: 16) keep unpadded storage and the a2a path pads
    transiently at call time instead."""
    E = cfg.moe.num_experts
    if "moe_a2a" in cfg.perf_flags and E >= 256:
        return -(-E // _A2A_PAD_TO) * _A2A_PAD_TO
    return E


def init_moe(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    E_store = a2a_padded_experts(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": _norm_init(ks[0], (d, E)),
        "wi": _norm_init(ks[1], (E_store, d, f)),
        "wg": _norm_init(ks[2], (E_store, d, f)),
        "wo": _norm_init(ks[3], (E_store, f, d)),
    }
    a = {
        "router": ("embed", None),
        "wi": ("expert", "embed", "ff"),
        "wg": ("expert", "embed", "ff"),
        "wo": ("expert", "ff", "embed"),
    }
    return p, a


def capacity(group_size: int, num_experts: int, top_k: int,
             capacity_factor: float) -> int:
    """Per-expert per-group token capacity (static)."""
    c = math.ceil(group_size * top_k * capacity_factor / num_experts)
    return max(4, c)


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
              group_size: int = MOE_GROUP_SIZE
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_load_balance_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    gsz = min(group_size, T)
    # pad T to a multiple of gsz (padding tokens route but are masked out)
    G = -(-T // gsz)
    Tp = G * gsz
    xt = x.reshape(T, d)
    if Tp != T:
        xt = jnp.pad(xt, ((0, Tp - T), (0, 0)))
    xg = xt.reshape(G, gsz, d)
    C = capacity(gsz, E, k, m.capacity_factor)

    from ..distributed import sharding as dist
    # anchor the token-group layout: without this GSPMD computed the whole
    # routing section replicated and re-gathered it per einsum — 12TB/step
    # of avoidable collectives on kimi-k2 (EXPERIMENTS.md §Perf, iter B1)
    xg = dist.constrain(xg, ("moe_groups", None, None))

    # ---- routing ------------------------------------------------------------
    # The router matmul runs in compute dtype and only the softmax is f32:
    # an f32 router input would give the (G,t,d)-sized router VJP an f32
    # dtype, poisoning the whole dispatch backward to f32 (2x collective
    # bytes on kimi; §Perf iter B3).
    logits = jnp.einsum("gtd,de->gte", xg,
                        p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,gsz,E)
    gates, idx = jax.lax.top_k(probs, k)                         # (G,gsz,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- capacity assignment (GShard) ---------------------------------------
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # (G,gsz,k,E)
    # token-major priority: flatten (t, k) with t outermost
    flat = onehot.reshape(G, gsz * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G,gsz*k,E)
    pos = pos.reshape(G, gsz, k, E)
    pos_k = jnp.sum(pos * onehot, axis=-1)                       # (G,gsz,k)
    fits = (pos_k < C) & (jnp.sum(onehot, -1) > 0)
    pos_oh = jax.nn.one_hot(pos_k.astype(jnp.int32), C,
                            dtype=jnp.float32)                   # (G,gsz,k,C)
    pos_oh = pos_oh * fits[..., None]
    # dispatch/combine over (E, C): contract the small k axis
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh)     # (G,gsz,E,C)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, gates)
    dispatch = dist.constrain(dispatch, ("moe_groups", None, None, None))
    combine = dist.constrain(combine, ("moe_groups", None, None, None))

    # ---- expert FFN (EP: the e axis shards per the "expert" rule) -----------
    # The constrain() pair below anchors the token-sharded -> expert-sharded
    # layout transition; GSPMD emits the MoE all-to-all exactly here.
    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if wi.shape[0] != E:                    # a2a-padded storage, dense path
        wi, wg, wo = wi[:E], wg[:E], wo[:E]
    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xin = dist.constrain(xin, (None, "expert", None, None))
    h = jnp.einsum("gecd,edf->gecf", xin, wi.astype(x.dtype))
    g = jnp.einsum("gecd,edf->gecf", xin, wg.astype(x.dtype))
    h = jax.nn.silu(g) * h
    out = jnp.einsum("gecf,efd->gecd", h, wo.astype(x.dtype))
    # (§Perf iter B2 tried sharding this tensor's d_model over "model" to
    # turn the f-contraction's all-reduce into a reduce-scatter; GSPMD kept
    # the all-reduce and added gathers — refuted, reverted.)
    out = dist.constrain(out, (None, "expert", None, None))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out)
    y = dist.constrain(y, ("moe_groups", None, None))
    # named for the remat policy: saving this small (g,t,d) tensor lets the
    # backward pass skip recomputing the out-projection and its all-reduce
    # over the kxcf-inflated (g,e,c,d) tensor (§Perf iter B5)
    y = jax.ad_checkpoint.checkpoint_name(y, "moe_out")

    y = y.reshape(Tp, d)[:T].reshape(B, S, d)

    # ---- Switch aux loss: E * sum_e f_e * p_e --------------------------------
    frac_tokens = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))     # top-1 fraction
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
