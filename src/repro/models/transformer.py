"""Model assembly: blocks -> stacked layers (lax.scan) -> full LM.

Covers all four block families of the assigned architectures:

  attn_mlp  — dense GQA transformer (yi, llama3, qwen, granite, chameleon)
  attn_moe  — GQA + mixture-of-experts FFN (kimi-k2, llama4-scout)
  ssm       — attention-free Mamba-2/SSD (mamba2-130m)
  hybrid    — parallel attention + SSD heads (hymba)

plus the whisper encoder-decoder (self + cross attention; audio frontend is a
stub: ``encode`` consumes precomputed frame embeddings).

Parameters are *stacked over layers* so the forward pass is a single
``lax.scan`` — the compiled HLO contains each layer body once, which keeps
dry-run compile times bounded and makes per-layer roofline extraction exact
(DESIGN.md §8).  ``cfg.remat`` wraps the scanned body in ``jax.checkpoint``.

Every ``init_*`` returns ``(params, axes)``; axes leaves are tuples of
logical axis names consumed by :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .moe import init_moe, moe_block

Params = Dict[str, Any]
Axes = Dict[str, Any]
PyTree = Any


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.block in ("attn_mlp", "attn_moe", "hybrid")


def _has_ssm(cfg: ModelConfig) -> bool:
    return cfg.block in ("ssm", "hybrid")


def _has_mlp(cfg: ModelConfig) -> bool:
    return cfg.block in ("attn_mlp", "hybrid") or (
        cfg.block == "ssm" and cfg.d_ff > 0)


def init_layer(key, cfg: ModelConfig, *, cross: bool = False
               ) -> Tuple[Params, Axes]:
    """One decoder block (``cross=True`` adds whisper cross-attention)."""
    ks = iter(jax.random.split(key, 8))
    p: Params = {}
    a: Axes = {}
    if _has_attn(cfg):
        p["ln1"], a["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["attn"], a["attn"] = L.init_attention(next(ks), cfg)
    if _has_ssm(cfg):
        p["lns"], a["lns"] = L.init_rmsnorm(cfg.d_model)
        p["ssm"], a["ssm"] = L.init_ssm(next(ks), cfg)
    if cross:
        p["lnx"], a["lnx"] = L.init_rmsnorm(cfg.d_model)
        p["xattn"], a["xattn"] = L.init_attention(next(ks), cfg)
    if _has_mlp(cfg):
        p["ln2"], a["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"], a["mlp"] = L.init_mlp(next(ks), cfg)
    if cfg.block == "attn_moe":
        p["ln2"], a["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"], a["moe"] = init_moe(next(ks), cfg)
    return p, a


def _stack_init(key, n: int, init_fn) -> Tuple[Params, Axes]:
    """vmap an init over n layer keys; prepend the "layers" logical axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree.map(lambda t: ("layers",) + tuple(t), axes,
                        is_leaf=lambda t: isinstance(t, tuple))
    return params, axes


def init_model(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    k_emb, k_layers, k_enc = jax.random.split(key, 3)
    p: Params = {}
    a: Axes = {}
    p["embed"], a["embed"] = L.init_embed(k_emb, cfg)
    cross = cfg.encoder is not None
    p["layers"], a["layers"] = _stack_init(
        k_layers, cfg.layers, functools.partial(init_layer, cfg=cfg,
                                                cross=cross))
    p["ln_f"], a["ln_f"] = L.init_rmsnorm(cfg.d_model)
    if cfg.encoder is not None:
        enc_cfg = cfg  # encoder blocks share dims with the decoder backbone
        p["enc_layers"], a["enc_layers"] = _stack_init(
            k_enc, cfg.encoder.layers,
            functools.partial(init_layer, cfg=enc_cfg, cross=False))
        p["enc_ln_f"], a["enc_ln_f"] = L.init_rmsnorm(cfg.d_model)
    if cfg.param_dtype == "bfloat16":
        # bf16 weight storage (norm scales stay f32 for stability)
        p = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x, p)
    return p, a


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Block application (train / prefill / decode share this body)
# ---------------------------------------------------------------------------

def block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array,
                enc_out: Optional[jax.Array] = None,
                cache: Optional[Dict[str, jax.Array]] = None,
                cache_index: Optional[jax.Array] = None,
                causal: bool = True,
                block_tables: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Apply one block.  Returns (x, aux_loss, new_cache).

    ``new_cache`` mirrors the input ``cache`` pytree exactly (untouched keys
    pass through) so lax.scan / lax.while decode loops keep a stable carry
    structure.
    """
    from ..distributed import sharding as dist
    x = dist.constrain(x, ("batch", "seq", None))
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, jax.Array] = dict(cache) if cache is not None else {}

    def _residual(y):
        # 'barrier_bf16' perf flag: pin the TP all-reduce of each block
        # output at bf16 — without the barrier XLA hoists the consumer's
        # f32 upcast above the all-reduce, doubling wire bytes (§Perf A2)
        if "barrier_bf16" in cfg.perf_flags:
            return jax.lax.optimization_barrier(y)
        return y

    if cfg.block == "hybrid":
        # parallel attention + SSD heads on the same normalized input
        att, kv = L.attention(
            p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
            positions=positions,
            cache=({"k": cache["k"], "v": cache["v"]} if cache else None),
            cache_index=cache_index, causal=causal,
            block_tables=block_tables)
        ssm_state = cache.get("ssm") if cache else None
        ssd, new_state = L.ssm_block(
            p["ssm"], L.rmsnorm(p["lns"], x, cfg.norm_eps), cfg,
            state=ssm_state)
        x = x + _residual(att) + _residual(ssd)
        if kv is not None:
            new_cache.update(kv)
        if cache is not None and new_state is not None:
            new_cache["ssm"] = new_state
    elif _has_attn(cfg):
        att, kv = L.attention(
            p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
            positions=positions,
            cache=({"k": cache["k"], "v": cache["v"]} if cache else None),
            cache_index=cache_index, causal=causal,
            block_tables=block_tables)
        x = x + _residual(att)
        if kv is not None:
            new_cache.update(kv)
    elif _has_ssm(cfg):
        ssm_state = cache.get("ssm") if cache else None
        ssd, new_state = L.ssm_block(
            p["ssm"], L.rmsnorm(p["lns"], x, cfg.norm_eps), cfg,
            state=ssm_state)
        x = x + _residual(ssd)
        if cache is not None and new_state is not None:
            new_cache["ssm"] = new_state

    if "xattn" in p:  # whisper cross-attention
        if cache is not None and "ck" in cache and enc_out is None:
            # decode: K/V over the encoder output were cached at prefill
            xa, _ = L.attention(
                p["xattn"], L.rmsnorm(p["lnx"], x, cfg.norm_eps), cfg,
                positions=positions, causal=False,
                precomputed_kv=(cache["ck"], cache["cv"]))
        else:
            xa, ckv = L.attention(
                p["xattn"], L.rmsnorm(p["lnx"], x, cfg.norm_eps), cfg,
                positions=positions, causal=False, context=enc_out,
                return_kv=True)
            if cache is not None:
                ck, cv = ckv
                new_cache["ck"] = ck.astype(cache["ck"].dtype)
                new_cache["cv"] = cv.astype(cache["cv"].dtype)
        x = x + _residual(xa)

    if "moe" in p:
        moe_fn = moe_block
        if "moe_a2a" in cfg.perf_flags:
            from ..distributed import sharding as _dist
            mesh = _dist.current_mesh()
            T = x.shape[0] * x.shape[1]
            if mesh is not None and "data" in mesh.axis_names:
                import numpy as _np
                n_dev = int(_np.prod([mesh.shape[a]
                                      for a in ("data", "model")
                                      if a in mesh.axis_names]))
                if T % n_dev == 0 and T // n_dev >= 1:
                    from .moe_a2a import moe_block_a2a
                    moe_fn = moe_block_a2a
        y, aux_moe = moe_fn(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps),
                            cfg)
        x = x + _residual(y)
        aux = aux + aux_moe
    elif "mlp" in p:
        x = x + _residual(L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps)))

    return x, aux, (new_cache if new_cache else None)


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Stacked-over-layers decode cache.

    Windowed archs get a ring buffer of size ``min(window, max_len)`` —
    long-context decode memory is O(window).  SSM blocks carry a recurrent
    state instead of (or, for hybrids, in addition to) KV rows.
    """
    Lc = cfg.layers
    c: Dict[str, jax.Array] = {}
    if _has_attn(cfg):
        W = min(cfg.window, max_len) if cfg.window else max_len
        kv_shape = (Lc, batch, W, cfg.kv_heads, cfg.hd)
        c["k"] = jnp.zeros(kv_shape, dtype)
        c["v"] = jnp.zeros(kv_shape, dtype)
    if _has_ssm(cfg):
        s = cfg.ssm
        c["ssm"] = jnp.zeros((Lc, batch, s.heads, s.state, s.head_dim),
                             jnp.float32)
    if cfg.encoder is not None:
        enc_S = cfg.encoder.seq_len
        c["ck"] = jnp.zeros((Lc, batch, enc_S, cfg.kv_heads, cfg.hd), dtype)
        c["cv"] = jnp.zeros((Lc, batch, enc_S, cfg.kv_heads, cfg.hd), dtype)
    return c


def cache_spec_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    """Logical axes of each cache leaf (for sharding).

    With the 'kv_cache_hd' perf flag the head_dim carries the "kv_hd"
    logical axis: when kv_heads is not divisible by the model axis (yi=4,
    llama3/kimi=8, hymba=5 on a 16-way axis) spec_for drops the kv_heads
    entry and the cache shards evenly on head_dim instead of replicating —
    16x less cache memory per device; attention contracts hd with a small
    per-layer all-reduce (EXPERIMENTS.md §Perf, decode cells)."""
    hd_ax = "kv_hd" if "kv_cache_hd" in cfg.perf_flags else None
    out: Dict[str, Tuple] = {}
    if _has_attn(cfg):
        out["k"] = ("layers", "batch", None, "kv_heads", hd_ax)
        out["v"] = ("layers", "batch", None, "kv_heads", hd_ax)
    if _has_ssm(cfg):
        out["ssm"] = ("layers", "batch", "ssm_heads", None, None)
    if cfg.encoder is not None:
        out["ck"] = ("layers", "batch", None, "kv_heads", hd_ax)
        out["cv"] = ("layers", "batch", None, "kv_heads", hd_ax)
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        # "full" still saves the named MoE block outputs: they are small
        # ((g,t,d), same scale as the residual stream) and skipping their
        # recompute removes the out-projection all-reduce from the backward
        # pass (6.5TB/step on kimi-k2; EXPERIMENTS.md §Perf iter B5)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "moe_out"))
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return fn


def _scan_layers(body, carry, xs, n: int, *, unroll: bool = False):
    """lax.scan over stacked layers, or a python loop when ``unroll``.

    The unrolled form exists for the roofline probes: ``cost_analysis``
    counts a while body once, so an unrolled L=2 lowering plus the scanned
    full lowering solve for (fixed, per-layer) costs exactly (DESIGN.md §8).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        layer = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, layer)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def encode(params: Params, cfg: ModelConfig, enc_embeds: jax.Array, *,
           unroll: bool = False) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (B, S_enc, d)."""
    x = enc_embeds.astype(_dtype(cfg))
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        y, _, _ = block_apply(lp, carry, cfg, positions=positions,
                              causal=False)
        return y, None

    x, _ = _scan_layers(_remat(body, cfg), x, params["enc_layers"],
                        cfg.encoder.layers, unroll=unroll)
    return L.rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            enc_embeds: Optional[jax.Array] = None,
            patch_embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            unroll: bool = False,
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (training / prefill without cache).

    Returns (logits (B,S,V), aux_loss scalar).

    - ``enc_embeds``  (whisper): precomputed audio frame embeddings.
    - ``patch_embeds`` (chameleon): precomputed VQ patch embeddings fused
      over the first P token positions (early fusion).
    """
    B, S = tokens.shape
    dtype = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    if patch_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(dtype), (0, 0, 0))
    if positions is None:
        positions = jnp.arange(S)

    enc_out = None
    if cfg.encoder is not None:
        assert enc_embeds is not None, "whisper needs encoder embeddings"
        enc_out = encode(params, cfg, enc_embeds, unroll=unroll)

    def body(carry, lp):
        y, aux = carry
        y, aux_l, _ = block_apply(lp, y, cfg, positions=positions,
                                  enc_out=enc_out, causal=True)
        return (y, aux + aux_l), None

    (x, aux), _ = _scan_layers(_remat(body, cfg),
                               (x, jnp.zeros((), jnp.float32)),
                               params["layers"], cfg.layers, unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, aux


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            cache: Dict[str, jax.Array], *,
            enc_embeds: Optional[jax.Array] = None,
            patch_embeds: Optional[jax.Array] = None,
            unroll: bool = False,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: full forward that also fills the decode cache.

    Returns (last-token logits (B,V), new cache).  The cache index after
    prefill is ``tokens.shape[1]`` (callers track it).
    """
    B, S = tokens.shape
    dtype = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    if patch_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(dtype), (0, 0, 0))
    positions = jnp.arange(S)
    enc_out = None
    if cfg.encoder is not None:
        assert enc_embeds is not None
        enc_out = encode(params, cfg, enc_embeds, unroll=unroll)

    idx0 = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        y, aux = carry
        lp, lc = xs
        y, aux_l, nc = block_apply(lp, y, cfg, positions=positions,
                                   enc_out=enc_out, cache=lc,
                                   cache_index=idx0, causal=True)
        return (y, aux + aux_l), nc

    (x, _), new_cache = _scan_layers(
        _remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache), cfg.layers, unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict[str, jax.Array], cache_index: jax.Array, *,
                unroll: bool = False,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: ``tokens`` (B, 1) -> (logits (B,V), new cache).

    ``cache_index`` may be a scalar (lockstep batch decode — the dry-run
    serve shapes) or an (B,) vector (continuous batching: each pool row at
    its own offset).
    """
    B, S = tokens.shape
    assert S == 1
    dtype = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    if jnp.ndim(cache_index) == 1:
        positions = cache_index[:, None] + jnp.arange(S)[None]
    else:
        positions = cache_index + jnp.arange(S)

    def body(carry, xs):
        lp, lc = xs
        y, _, nc = block_apply(lp, carry, cfg, positions=positions,
                               cache=lc, cache_index=cache_index,
                               causal=True)
        return y, nc

    x, new_cache = _scan_layers(body, x, (params["layers"], cache),
                                cfg.layers, unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged (block-pool) serving path
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, num_blocks: int, page_size: int,
                     batch: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Block-pool decode cache for the paged serving engine.

    Attention K/V live in a shared pool of ``num_blocks`` fixed-size blocks
    of ``page_size`` token positions each — requests own non-contiguous
    block lists (their *block table*), so memory scales with live tokens,
    not ``max_batch × max_len``.  Block 0 is conventionally the garbage
    block (never allocated; dead decode rows write there).  SSM recurrent
    state is O(1) per sequence and stays per-slot, keyed by decode row.
    Encoder-decoder configs are not served by the paged engine (the CLI
    rejects them too).
    """
    if cfg.encoder is not None:
        raise ValueError("paged serving does not support encoder-decoder "
                         "configs")
    Lc = cfg.layers
    c: Dict[str, jax.Array] = {}
    if _has_attn(cfg):
        c["k"] = jnp.zeros((Lc, num_blocks, page_size, cfg.kv_heads, cfg.hd),
                           dtype)
        c["v"] = jnp.zeros((Lc, num_blocks, page_size, cfg.kv_heads, cfg.hd),
                           dtype)
    if _has_ssm(cfg):
        s = cfg.ssm
        c["ssm"] = jnp.zeros((Lc, batch, s.heads, s.state, s.head_dim),
                             jnp.float32)
    return c


def paged_copy_block(cache: Dict[str, jax.Array], src: jax.Array,
                     dst: jax.Array) -> Dict[str, jax.Array]:
    """Copy-on-write duplication: copy physical KV block ``src`` into
    ``dst`` across every layer, for both K and V pool leaves.

    The serving engine calls this before a tick writes into a block whose
    refcount is above one (prefix-shared with another sequence or pinned
    by the prefix index): the writer gets a private copy, other owners
    keep reading the original.  Per-slot SSM state is not paged and never
    shared, so only the block-pool leaves move.  ``src``/``dst`` are
    scalar block ids — shape-stable, so the jit'd copy compiles once.
    """
    out = dict(cache)
    for key in ("k", "v"):
        if key in cache:
            out[key] = cache[key].at[:, dst].set(
                jax.lax.dynamic_index_in_dim(cache[key], src, axis=1,
                                             keepdims=False))
    return out


def paged_prefill_chunk(params: Params, cfg: ModelConfig, tokens: jax.Array,
                        cache: Dict[str, jax.Array], cache_index: jax.Array,
                        block_table: jax.Array, slot: jax.Array, *,
                        unroll: bool = False,
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunk of a paged prefill: ``tokens`` (1, C) at logical offset
    ``cache_index`` of the sequence whose block table is ``block_table``
    (1, nblk) and whose decode-pool row (SSM state) is ``slot``.

    Chunks carry no padding (the engine quantizes chunk lengths instead),
    so the recurrent SSM state threads exactly and the returned last-token
    logits of the *final* chunk equal whole-prompt prefill's.  Returns
    (last-token logits (1, V), new cache); the caller tracks the index.
    """
    B, S = tokens.shape
    dtype = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    positions = cache_index + jnp.arange(S)
    has_ssm = _has_ssm(cfg)

    def body(carry, xs):
        lp, lc = xs
        lc_in = dict(lc)
        if has_ssm:
            lc_in["ssm"] = jax.lax.dynamic_slice_in_dim(
                lc["ssm"], slot, 1, axis=0)
        y, _, nc = block_apply(lp, carry, cfg, positions=positions,
                               cache=lc_in, cache_index=cache_index,
                               causal=True, block_tables=block_table)
        if has_ssm:
            nc["ssm"] = jax.lax.dynamic_update_slice_in_dim(
                lc["ssm"], nc["ssm"], slot, axis=0)
        return y, nc

    x, new_cache = _scan_layers(_remat(body, cfg), x,
                                (params["layers"], cache), cfg.layers,
                                unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, new_cache


def paged_decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                      cache: Dict[str, jax.Array], cache_index: jax.Array,
                      block_tables: jax.Array, *,
                      ssm_mask: Optional[jax.Array] = None,
                      unroll: bool = False,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step over the paged pool: ``tokens`` (B, 1) with per-row
    ``cache_index`` (B,) and ``block_tables`` (B, nblk).

    Dead rows point their whole table at the garbage block (0) with index
    0; their writes land there and their logits are ignored by the engine.
    KV writes of non-decoding rows are harmless (garbage block), but the
    recurrent SSM state is per-slot and *would* absorb their garbage step —
    ``ssm_mask`` (B,) bool keeps the old state for rows not decoding (dead
    slots, and slots whose chunked prefill is still in flight).
    """
    B, S = tokens.shape
    assert S == 1
    dtype = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    positions = cache_index[:, None] + jnp.arange(S)[None]

    def body(carry, xs):
        lp, lc = xs
        y, _, nc = block_apply(lp, carry, cfg, positions=positions,
                               cache=lc, cache_index=cache_index,
                               causal=True, block_tables=block_tables)
        if ssm_mask is not None and "ssm" in nc:
            keep = ssm_mask[:, None, None, None]
            nc["ssm"] = jnp.where(keep, nc["ssm"], lc["ssm"])
        return y, nc

    x, new_cache = _scan_layers(body, x, (params["layers"], cache),
                                cfg.layers, unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, new_cache
