"""Hand-written all-to-all MoE dispatch (shard_map) — the 'moe_a2a' flag.

EXPERIMENTS.md §Perf B shows GSPMD lowers the GShard dispatch to full
all-gathers (~5x the intrinsic dispatch bytes on kimi-k2).  This module
writes the collective schedule by hand, the way DeepSpeed-MoE / MaxText
expert-parallel paths do:

  * experts are distributed over ALL mesh devices (data x model), padded up
    to a multiple of the device count (kimi: 384 -> 512, 2 per device;
    phantom experts receive no tokens and their capacity rows are zeros);
  * each device routes its own token groups locally, builds the dispatched
    tensor (G_local, E, C, d), and a single `lax.all_to_all` over
    (data, model) exchanges it for (G, E_local, C, d): every device then
    holds ALL token groups for ITS experts;
  * the expert FFN is fully local — d and f are unsharded, so there is no
    TP all-reduce on the k*cf-inflated tensor at all;
  * a second all_to_all brings expert outputs home; combine is local.

Wire bytes per device per call ~= 2 x |dispatched tensor| x (n-1)/n — the
intrinsic top-k dispatch cost, nothing else.

Constraints: token count per device must be >= 1 group (decode-sized
batches fall back to the dense GShard path), and E must divide by the
device count after padding.  Gradient flow works through shard_map +
all_to_all (both differentiable).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .moe import capacity

Params = Dict[str, Any]


def _routing(xg, router, E, k, C, dtype):
    """Local GShard routing: returns (dispatch, combine, probs) for one
    shard's groups.  xg: (G_l, gsz, d)."""
    logits = jnp.einsum("gtd,de->gte", xg, router.astype(dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    G_l, gsz = xg.shape[0], xg.shape[1]
    flat = onehot.reshape(G_l, gsz * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = pos.reshape(G_l, gsz, k, E)
    pos_k = jnp.sum(pos * onehot, axis=-1)
    fits = (pos_k < C) & (jnp.sum(onehot, -1) > 0)
    pos_oh = jax.nn.one_hot(pos_k.astype(jnp.int32), C,
                            dtype=jnp.float32) * fits[..., None]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, gates)
    return dispatch, combine, probs


def moe_block_a2a(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  group_size: int = 1024) -> Tuple[jax.Array, jax.Array]:
    """Drop-in replacement for moe_block using explicit all_to_all.

    Requires an active mesh with a 'data' axis; otherwise (and for
    decode-sized token counts) the caller should use the dense path.
    """
    from ..distributed import sharding as dist
    mesh = dist.current_mesh()
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S

    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    E_store = p["wi"].shape[0]
    if E_store > E and E_store % n_dev == 0:
        # weights stored pre-padded in the a2a layout (init_moe under the
        # flag): zero weight resharding inside the shard_map — the fix for
        # §Perf iter B6's 33.8 GB/layer/mb regression
        E_pad = E_store
        pre_padded = True
    else:
        E_pad = -(-E // n_dev) * n_dev
        pre_padded = False
    E_l = E_pad // n_dev

    # groups: one shard of tokens per device along 'data'; the 'model'
    # ranks subdivide those groups so the a2a runs over both axes
    n_data = mesh.shape.get("data", 1)
    n_model = mesh.shape.get("model", 1)
    gsz = min(group_size, max(1, T // n_dev))
    G = T // gsz
    assert T % gsz == 0 and G % n_dev == 0, (
        f"moe_a2a needs tokens to tile over {n_dev} devices: T={T} gsz={gsz}")
    C = capacity(gsz, E, k, m.capacity_factor)

    xg = x.reshape(G, gsz, d)

    def local(xg_l, router, wi_l, wg_l, wo_l):
        # xg_l: (G/n_dev, gsz, d); w*_l: (E_l, d, f) own experts
        G_l = xg_l.shape[0]
        dtype = xg_l.dtype
        dispatch, combine, probs = _routing(xg_l, router, E, k, C, dtype)
        # pad expert dim to E_pad (phantom experts receive no tokens)
        pad = E_pad - E
        disp_p = jnp.pad(dispatch, ((0, 0), (0, 0), (0, pad), (0, 0)))
        xin = jnp.einsum("gtec,gtd->gecd", disp_p.astype(dtype), xg_l)
        # exchange: split the expert dim n_dev-ways, concat on groups —
        # every device then holds ALL token groups for ITS E_l experts
        xin = jax.lax.all_to_all(xin, axes, split_axis=1, concat_axis=0,
                                 tiled=True)            # (G, E_l, C, d)
        h = jnp.einsum("gecd,edf->gecf", xin, wi_l.astype(dtype))
        g = jnp.einsum("gecd,edf->gecf", xin, wg_l.astype(dtype))
        h = jax.nn.silu(g) * h
        out = jnp.einsum("gecf,efd->gecd", h, wo_l.astype(dtype))
        # inverse exchange: outputs come home, experts re-concatenate
        out = jax.lax.all_to_all(out, axes, split_axis=0, concat_axis=1,
                                 tiled=True)            # (G_l, E_pad, C, d)
        out = out[:, :E]
        y_l = jnp.einsum("gtec,gecd->gtd", combine.astype(dtype), out)
        # load-balance stats (global means via psum over all axes)
        ft = jnp.mean(jax.nn.one_hot(
            jnp.argmax(probs, -1), E, dtype=jnp.float32), axis=(0, 1))
        fp = jnp.mean(probs, axis=(0, 1))
        ft = jax.lax.pmean(ft, axes)
        fp = jax.lax.pmean(fp, axes)
        aux = E * jnp.sum(ft * fp)
        return y_l, aux

    # weights: experts padded then split over (data, model)
    def pad_w(w):
        if pre_padded:
            return w
        return jnp.pad(w, ((0, E_pad - E), (0, 0), (0, 0)))

    espec = P(axes if len(axes) > 1 else axes[0])
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axes if len(axes) > 1 else axes[0], None, None),
                  P(None, None),
                  P(*espec, None, None), P(*espec, None, None),
                  P(*espec, None, None)),
        out_specs=(P(axes if len(axes) > 1 else axes[0], None, None), P()),
        check_rep=False)
    y, aux = fn(xg, p["router"],
                pad_w(p["wi"]), pad_w(p["wg"]), pad_w(p["wo"]))
    return y.reshape(B, S, d), aux
