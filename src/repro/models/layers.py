"""Functional model layers (no framework deps; pjit/GSPMD-friendly).

Every ``init_*`` returns ``(params, axes)`` — two parallel pytrees, the
second holding *logical axis names* per parameter dimension.  The
distributed layer maps logical axes -> mesh axes (MaxText-style rules), so
the same model code runs on 1 CPU device and on the 512-chip mesh.

Attention/SSD hot-paths route through the comprehensive-tree kernels on TPU
(`repro.kernels.ops`) and through equivalent einsum math elsewhere; both are
validated against `repro.kernels.ref` oracles.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from ..kernels.ssd_scan import ssd_chunk

Params = Dict[str, Any]
Axes = Dict[str, Any]
NEG_INF = -1e30

# When True, inner lax.scan loops (SSD chunk scan, blocked-attention q loop)
# are unrolled at trace time.  Only the roofline probes set this: XLA's
# cost_analysis counts a while body once, so probes must make every loop
# body explicit to measure true per-layer FLOPs/bytes (DESIGN.md §8).
_UNROLL_INNER = False


def set_unroll_inner(value: bool) -> None:
    global _UNROLL_INNER
    _UNROLL_INNER = bool(value)


def _inner_scan(body, carry, xs, length: int):
    if not _UNROLL_INNER:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
    return carry, ys


def _norm_init(key, shape, scale=1.0, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    return jax.random.normal(key, shape, dtype) * (scale / max(1, fan_in) ** 0.5)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Tuple[Params, Axes]:
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float,
         compute_dtype=None) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S).

    Angles are always f32; with ``compute_dtype`` the cos/sin tables are
    cast before the elementwise rotation so the (B,S,H,hd)-sized
    intermediates stay in the compute dtype instead of f32 (the
    'rope_compute' perf flag — halves rope HBM traffic; cos/sin in bf16
    carry ~4e-3 relative error on the rotation, fine for training)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    if compute_dtype is not None:
        cos = cos.astype(compute_dtype)
        sin = sin.astype(compute_dtype)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + causal/window masks + optional KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    d, nh, nk, hd = cfg.d_model, cfg.heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _norm_init(ks[0], (d, nh * hd)),
        "wk": _norm_init(ks[1], (d, nk * hd)),
        "wv": _norm_init(ks[2], (d, nk * hd)),
        "wo": _norm_init(ks[3], (nh * hd, d)),
    }
    a = {
        "wq": ("embed", "q_proj"),
        "wk": ("embed", "kv_proj"),
        "wv": ("embed", "kv_proj"),
        "wo": ("q_proj", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nk * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nk * hd,), jnp.float32)
        a["bq"], a["bk"], a["bv"] = ("q_proj",), ("kv_proj",), ("kv_proj",)
    return p, a


def _sdpa(q, k, v, *, causal: bool, window: Optional[int],
          q_positions, k_positions, flags: Tuple[str, ...] = ()) -> jax.Array:
    """q: (B,Sq,nh,hd) k/v: (B,Sk,nk,hd); GQA by head grouping; f32 softmax.

    Positions may be shared (1D) or per-row (2D, continuous batching where
    each sequence in the decode pool sits at its own offset).

    perf flags:
      attn_q_heads — repeat K/V to the query-head count and contract over a
        single head axis: the head dim is then nh (divisible by the model
        axis on every assigned arch) instead of nk, so GSPMD shards the
        scores/probs tensors instead of replicating them when nk < mesh.
      probs_bf16 — probabilities leave the f32 softmax in compute dtype,
        halving the largest attention tensors; PV accumulates in f32.
    """
    B, Sq, nh, hd = q.shape
    nk = k.shape[2]
    group = nh // nk
    qp = q_positions if q_positions.ndim == 2 else q_positions[None]
    kp = k_positions if k_positions.ndim == 2 else k_positions[None]
    qi = qp[:, :, None]                # (B|1, Sq, 1)
    ki = kp[:, None, :]                # (B|1, 1, Sk)
    mask = ki >= 0                     # ring slots that were never written
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    mask = jnp.broadcast_to(mask, (mask.shape[0], Sq, k.shape[1]))

    if "attn_q_heads" in flags and group > 1:
        kq = jnp.repeat(k, group, axis=2)          # (B,Sk,nh,hd)
        vq = jnp.repeat(v, group, axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq,
                            preferred_element_type=jnp.float32) / (hd ** 0.5)
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if "probs_bf16" in flags:
            probs = probs.astype(q.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq,
                             preferred_element_type=jnp.float32)
        else:
            out = jnp.einsum("bhqk,bkhd->bqhd", probs,
                             vq.astype(jnp.float32))
        return out.astype(q.dtype)

    qf = q.astype(jnp.float32).reshape(B, Sq, nk, group, hd)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / (hd ** 0.5)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if "probs_bf16" in flags:
        probs = probs.astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, nh, hd).astype(q.dtype)


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              cache: Optional[Dict[str, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              causal: bool = True,
              context: Optional[jax.Array] = None,
              precomputed_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              return_kv: bool = False,
              block_tables: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- (or cross-, when ``context`` given) attention.

    cache: {"k","v"} of shape (B, S_max, nk, hd); cache_index: scalar int —
    new k/v are written at [cache_index : cache_index+Sq].
    precomputed_kv: projected (k, v) (B,Sk,nk,hd) — whisper decode reuses the
    cross K/V cached at prefill and skips the projections.
    return_kv: return the projected (k, v) instead of a cache dict (the
    whisper prefill writes them into the cross cache).
    block_tables: (B, nblk) int32 — *paged* KV cache.  The cache leaves are
    then block pools of shape (num_blocks, page_size, nk, hd) shared by
    every sequence, and row ``b``'s logical block ``j`` lives in physical
    block ``block_tables[b, j]``.  Unallocated entries may point anywhere
    (conventionally the engine's garbage block 0): their logical positions
    lie beyond the row's ``cache_index`` and are causally masked.
    """
    B, Sq, d = x.shape
    nh, nk, hd = cfg.heads, cfg.kv_heads, cfg.hd
    src = x if context is None else context
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, Sq, nh, hd)
    if precomputed_kv is not None:
        k, v = precomputed_kv
        k = k.astype(x.dtype)
        v = v.astype(x.dtype)
    else:
        k = jnp.einsum("bsd,dh->bsh", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dh->bsh", src, p["wv"].astype(x.dtype))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        k = k.reshape(B, -1, nk, hd)
        v = v.reshape(B, -1, nk, hd)

    if context is None and precomputed_kv is None:   # rope: self-attn only
        rope_dt = x.dtype if "rope_compute" in cfg.perf_flags else None
        q = rope(q, positions, cfg.rope_theta, compute_dtype=rope_dt)
        k = rope(k, positions, cfg.rope_theta, compute_dtype=rope_dt)
    elif precomputed_kv is not None:
        pass                                          # cross-attn: no rope

    new_cache = None
    if cache is not None and block_tables is not None:
        # Paged KV pool (serving): scatter this call's K/V into the rows'
        # physical blocks, then gather each row's logical view for the
        # attention read.  Works for both the per-row decode step
        # (cache_index (B,), Sq == 1) and the batch-1 chunked-prefill step
        # (scalar cache_index, Sq == chunk).  Window semantics come from
        # the sdpa mask, not a ring buffer — the pool is position-exact.
        ps = cache["k"].shape[1]
        idxv = (cache_index if jnp.ndim(cache_index) == 1
                else jnp.broadcast_to(cache_index, (B,)))
        ptok = idxv[:, None] + jnp.arange(Sq)[None]          # (B,Sq) logical
        phys = jnp.take_along_axis(block_tables, ptok // ps, axis=1)
        pslot = ptok % ps
        ck = cache["k"].at[phys, pslot].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[phys, pslot].set(v.astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        nblk = block_tables.shape[1]
        k_att = ck[block_tables].reshape(B, nblk * ps, nk, hd).astype(x.dtype)
        v_att = cv[block_tables].reshape(B, nblk * ps, nk, hd).astype(x.dtype)
        k_positions = jnp.arange(nblk * ps)
    elif cache is not None:
        k_len = cache["k"].shape[1]
        ring = cfg.window is not None and k_len <= cfg.window
        vec_idx = cache_index is not None and jnp.ndim(cache_index) == 1
        if ring:
            # Ring buffer of size W: token t lives at slot t % W.  Slot j
            # currently holds token  t_last - ((t_last - j) mod W); negative
            # values mean "never written" and are masked out.  This keeps the
            # long-context decode cache at O(window), not O(S_max).
            idxv = jnp.broadcast_to(cache_index, (B,))
            if Sq >= k_len:
                # prefill longer than the window: only the last W tokens
                # matter (distinct slots; avoids duplicate-index scatter)
                kw_, vw_ = k[:, -k_len:], v[:, -k_len:]
                slots = (idxv[:, None] + Sq - k_len +
                         jnp.arange(k_len)[None]) % k_len
            else:
                kw_, vw_ = k, v
                slots = (idxv[:, None] + jnp.arange(Sq)[None]) % k_len
            rows = jnp.arange(B)[:, None]
            ck = cache["k"].at[rows, slots].set(kw_.astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slots].set(vw_.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            if Sq > 1:
                # prefill: attend in-sequence (chunked windowed path); the
                # ring is only written for the decode steps that follow.
                k_att, v_att, k_positions = k, v, positions
            else:
                t_last = idxv[:, None] + Sq - 1                  # (B,1)
                k_positions = t_last - ((t_last - jnp.arange(k_len)[None])
                                        % k_len)                 # (B,W)
                k_att, v_att = ck.astype(x.dtype), cv.astype(x.dtype)
        elif vec_idx:
            # continuous batching: every pool row sits at its own offset
            rows = jnp.arange(B)[:, None]
            slots = cache_index[:, None] + jnp.arange(Sq)[None]
            ck = cache["k"].at[rows, slots].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slots].set(v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            k_att, v_att = ck.astype(x.dtype), cv.astype(x.dtype)
            k_positions = jnp.arange(k_len)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
            new_cache = {"k": ck, "v": cv}
            if cfg.window is not None and cfg.window < k_len:
                # sliding window over a full-length cache: slice the last
                # `window` rows so decode cost is O(window), not O(S_max).
                w = cfg.window
                start = jnp.clip(cache_index + Sq - w, 0, k_len - w)
                k_att = jax.lax.dynamic_slice(ck, (0, start, 0, 0),
                                              (B, w, nk, hd))
                v_att = jax.lax.dynamic_slice(cv, (0, start, 0, 0),
                                              (B, w, nk, hd))
                k_positions = start + jnp.arange(w)
            else:
                k_att, v_att = ck.astype(x.dtype), cv.astype(x.dtype)
                k_positions = jnp.arange(k_len)
    else:
        k_att, v_att = k, v
        k_positions = (positions
                       if context is None and precomputed_kv is None
                       else jnp.arange(k.shape[1]))
    cross = context is not None or precomputed_kv is not None
    if cache is not None and "kv_cache_hd" in cfg.perf_flags:
        # the cache is head_dim-sharded; matching q makes GSPMD compute the
        # QK contraction distributed (partial scores + ~65MB all-reduce)
        # instead of all-gathering the ~1GB K cache per layer (§Perf C2)
        from ..distributed import sharding as dist
        q = dist.constrain(q, ("batch", None, None, "kv_hd"))
    out = sdpa_auto(q, k_att, v_att,
                    causal=causal and not cross,
                    window=cfg.window if not cross else None,
                    q_positions=positions, k_positions=k_positions,
                    flags=cfg.perf_flags)
    out = out.reshape(B, Sq, nh * hd)
    proj = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return proj, (k, v)
    return proj, new_cache


def _sdpa_chunked(q, k, v, *, causal: bool, window: Optional[int],
                  q_positions, k_positions, q_block: int = 1024,
                  flags: Tuple[str, ...] = ()) -> jax.Array:
    """Flash-style blocked attention in pure XLA (lax.scan over Q blocks).

    Keeps peak memory at O(q_block × S_k) instead of O(S_q × S_k) so the
    32k-prefill cells lower with realistic (flash-equivalent) HBM traffic.
    Windowed attention additionally slices only the K rows a Q block can see,
    making the whole pass O(S·W) — the sub-quadratic path the hybrid archs
    use for long contexts.  Perf flags as in :func:`_sdpa`.
    """
    B, Sq, nh, hd = q.shape
    Sk, nk = k.shape[1], k.shape[2]
    group = nh // nk
    q_heads = "attn_q_heads" in flags and group > 1
    qb = min(q_block, Sq)
    nq = -(-Sq // qb)
    Sqp = nq * qb
    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, Sqp - Sq), constant_values=-1)
    if q_heads:
        kf = jnp.repeat(k, group, axis=2)      # (B,Sk,nh,hd) compute dtype
        vf = jnp.repeat(v, group, axis=2)
    else:
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)

    if window is not None:
        kw = window + qb                       # rows a q-block can see

    def body(_, inp):
        qc, qp_c, qstart = inp                 # (B,qb,nh,hd), (qb,), scalar
        nh_k = nh if q_heads else nk
        if window is not None:
            start = jnp.clip(qstart - window + 1, 0, max(Sk - kw, 0))
            kc = jax.lax.dynamic_slice(kf, (0, start, 0, 0),
                                       (B, min(kw, Sk), nh_k, hd))
            vc = jax.lax.dynamic_slice(vf, (0, start, 0, 0),
                                       (B, min(kw, Sk), nh_k, hd))
            kp = start + jnp.arange(min(kw, Sk))
            kp = jnp.take(k_positions, kp, axis=0) \
                if k_positions.shape[0] == Sk else kp
        else:
            kc, vc, kp = kf, vf, k_positions
        mask = jnp.ones((qb, kp.shape[0]), bool)
        qi = qp_c[:, None]
        ki = kp[None, :]
        mask &= ki >= 0
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= ki > qi - window
        mask &= qi >= 0                        # padded q rows
        if q_heads:
            scores = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                                preferred_element_type=jnp.float32) \
                / (hd ** 0.5)
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            if "probs_bf16" in flags:
                probs = probs.astype(q.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, vc,
                             preferred_element_type=jnp.float32)
            return None, out.astype(q.dtype)
        qf = qc.astype(jnp.float32).reshape(B, qb, nk, group, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc) / (hd ** 0.5)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if "probs_bf16" in flags:
            probs = probs.astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vc,
                         preferred_element_type=jnp.float32)
        return None, out.reshape(B, qb, nh, hd).astype(q.dtype)

    xs = (qp.reshape(B, nq, qb, nh, hd).transpose(1, 0, 2, 3, 4),
          qpos.reshape(nq, qb),
          jnp.arange(nq) * qb)
    _, outs = _inner_scan(body, None, xs, nq)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sqp, nh, hd)
    return out[:, :Sq].astype(q.dtype)


# XLA attention dispatch: dense for short sequences, blocked beyond this.
CHUNKED_SDPA_THRESHOLD = 4096


def sdpa_auto(q, k, v, *, causal: bool, window: Optional[int],
              q_positions, k_positions,
              flags: Tuple[str, ...] = ()) -> jax.Array:
    if q.shape[1] >= CHUNKED_SDPA_THRESHOLD or (
            window is not None and q.shape[1] > window):
        return _sdpa_chunked(q, k, v, causal=causal, window=window,
                             q_positions=q_positions,
                             k_positions=k_positions, flags=flags)
    return _sdpa(q, k, v, causal=causal, window=window,
                 q_positions=q_positions, k_positions=k_positions,
                 flags=flags)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": _norm_init(ks[0], (d, f)), "wg": _norm_init(ks[1], (d, f)),
         "wo": _norm_init(ks[2], (f, d))}
    a = {"wi": ("embed", "ff"), "wg": ("embed", "ff"), "wo": ("ff", "embed")}
    return p, a


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# SSD (Mamba-2) block
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    """Mamba-2 SSD projections.  B and C are shared across heads
    (ngroups=1, as in the paper) — (d, state), not (d, heads*state)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.heads * s.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wx": _norm_init(ks[0], (d, di)),
        "wb": _norm_init(ks[1], (d, s.state)),
        "wc": _norm_init(ks[2], (d, s.state)),
        "wa": _norm_init(ks[3], (d, s.heads), scale=0.1),
        "wo": _norm_init(ks[4], (di, d)),
        "a_bias": jnp.full((s.heads,), 2.0, jnp.float32),
    }
    a = {
        "wx": ("embed", "ssm_inner"), "wb": ("embed", "ssm_bc"),
        "wc": ("embed", "ssm_bc"), "wa": ("embed", "ssm_heads"),
        "wo": ("ssm_inner", "embed"), "a_bias": ("ssm_heads",),
    }
    return p, a


def ssm_decays(p: Params, x: jax.Array, s) -> jax.Array:
    """Per-token decay a_t in (0,1): sigmoid(x·wa + bias)."""
    logit = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                       p["wa"].astype(jnp.float32)) + p["a_bias"]
    return jax.nn.sigmoid(logit)


def ssm_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
              state: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """SSD block; ``state`` (B, heads, state, hd) enables O(1) decode.

    Training path runs the chunked matmul-form scan (`ssd_chunk`, shared with
    the Pallas kernel).  Decode path applies one recurrence step.
    """
    s = cfg.ssm
    B, S, d = x.shape
    xi = jnp.einsum("bsd,di->bsi", x, p["wx"].astype(x.dtype))
    xi = xi.reshape(B, S, s.heads, s.head_dim)
    # B/C shared across heads (ngroups=1): project once, broadcast to heads
    b1 = jnp.einsum("bsd,dn->bsn", x, p["wb"].astype(x.dtype))
    c1 = jnp.einsum("bsd,dn->bsn", x, p["wc"].astype(x.dtype))
    b = jnp.broadcast_to(b1[:, :, None, :], (B, S, s.heads, s.state))
    c = jnp.broadcast_to(c1[:, :, None, :], (B, S, s.heads, s.state))
    a = ssm_decays(p, x, s)                                   # (B,S,H)

    if state is not None and S == 1:
        # one-step recurrence: S_t = a*S + b⊗x ; y = c·S
        xf = xi[:, 0].astype(jnp.float32)                     # (B,H,hd)
        bf = b[:, 0].astype(jnp.float32)                      # (B,H,st)
        cf = c[:, 0].astype(jnp.float32)
        af = a[:, 0]                                          # (B,H)
        new_state = af[..., None, None] * state + \
            jnp.einsum("bhs,bhd->bhsd", bf, xf)
        y = jnp.einsum("bhs,bhsd->bhd", cf, new_state)[:, None]
        y = y.astype(x.dtype)
        new_state_out = new_state
    else:
        # chunked scan over the sequence (matmul form, shared with kernel)
        ck = min(s.chunk, S)
        Sp = -(-S // ck) * ck
        pad = Sp - S
        xi_p = jnp.pad(xi, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_p = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nchunks = Sp // ck

        def chunk_body(S_prev, inp):
            xc, ac, bc, cc = inp                              # (B,ck,H,*)
            def per_bh(Sp_bh, x_bh, a_bh, b_bh, c_bh):
                return ssd_chunk(x_bh, a_bh, b_bh, c_bh, Sp_bh)
            # vmap over batch and heads
            f = jax.vmap(jax.vmap(
                lambda S0, xx, aa, bb, cc2: ssd_chunk(xx, aa, bb, cc2, S0)))
            y, S_new = f(S_prev,
                         xc.transpose(0, 2, 1, 3).astype(jnp.float32),
                         ac.transpose(0, 2, 1).astype(jnp.float32),
                         bc.transpose(0, 2, 1, 3).astype(jnp.float32),
                         cc.transpose(0, 2, 1, 3).astype(jnp.float32))
            return S_new, y                                   # y: (B,H,ck,hd)

        S0 = (state if state is not None
              else jnp.zeros((B, s.heads, s.state, s.head_dim), jnp.float32))
        xs = (xi_p.reshape(B, nchunks, ck, s.heads, s.head_dim).transpose(1, 0, 2, 3, 4),
              a_p.reshape(B, nchunks, ck, s.heads).transpose(1, 0, 2, 3),
              b_p.reshape(B, nchunks, ck, s.heads, s.state).transpose(1, 0, 2, 3, 4),
              c_p.reshape(B, nchunks, ck, s.heads, s.state).transpose(1, 0, 2, 3, 4))
        S_fin, ys = _inner_scan(chunk_body, S0, xs, nchunks)
        y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Sp, s.heads, s.head_dim)
        y = y[:, :S].astype(x.dtype)
        new_state_out = S_fin

    y = y.reshape(B, S, s.heads * s.head_dim)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"].astype(x.dtype))
    return out, new_state_out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 2)
    p = {"tok": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                  jnp.float32) * 0.02,
         "out": _norm_init(ks[1], (cfg.d_model, cfg.vocab))}
    a = {"tok": ("vocab", "embed"), "out": ("embed", "vocab")}
    return p, a


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["tok"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,dv->bsv", x, p["out"].astype(x.dtype))
