"""Training launcher (runs for real on whatever devices exist).

On the CPU container this trains reduced configs end-to-end with the full
production stack — mesh + sharded train_step + stateless data pipeline +
async checkpointing + restart-on-failure — the same code path the 512-chip
job would take (only the mesh and config scale change).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM, DataConfig
from repro.distributed import sharding as dist
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import grad_dtype_for, state_shardings, abstract_state
from repro.models import init_model
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime import TrainController, build_train_step
from repro.runtime.steps import build_eval_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    rules = dist.rules_for(cfg, mesh)
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(args.lr, 10, args.steps))
    step_fn = build_train_step(cfg, opt, microbatches=args.microbatches,
                               grad_dtype=grad_dtype_for(cfg))

    with mesh, dist.use_mesh_rules(mesh, rules):
        params, axes = init_model(jax.random.PRNGKey(args.seed), cfg)
        opt_state = opt.init(params)
        p_sh, o_sh, _ = state_shardings(cfg, mesh, params, axes, opt_state)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None, None),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))

        ds = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                    global_batch=args.global_batch,
                                    seed=args.seed))
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)

        def run_step(state, step):
            params, opt_state = state
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            if cfg.encoder is not None:
                batch["enc_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(step),
                    (args.global_batch, cfg.encoder.seq_len, cfg.d_model),
                    jnp.float32)
            params, opt_state, metrics = jitted(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            return (params, opt_state), {k: float(v)
                                         for k, v in metrics.items()}

        # resume if a checkpoint exists
        start = 0
        restored_step, restored = ckpt.restore_latest((params, opt_state))
        if restored is not None:
            (params, opt_state) = jax.device_put(restored, (p_sh, o_sh))
            start = restored_step
            print(f"resumed from step {start}")

        ctl = TrainController(run_step, ckpt, ckpt_every=args.ckpt_every)
        t0 = time.time()
        (params, opt_state), hist = ctl.run(
            (params, opt_state), start_step=start, num_steps=args.steps)
        dt = time.time() - t0

    for h in hist[::max(1, len(hist) // (args.steps // args.log_every or 1))]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  {h['step_time_s']*1e3:.0f}ms")
    toks = args.steps * args.global_batch * args.seq_len
    print(f"done: {len(hist)} steps, {toks/dt:.0f} tok/s, "
          f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
