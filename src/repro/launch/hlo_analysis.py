"""Post-SPMD HLO analysis: collective bytes, op counts, loop-weighted totals.

``cost_analysis`` does not expose collective traffic, so we parse the
partitioned HLO text (``compiled.as_text()``):

1. split the module into named computations;
2. find every all-reduce / all-gather / reduce-scatter / all-to-all /
   collective-permute (sync or ``-start`` async form) and compute the bytes
   it moves per device from its result shape, its replica-group size and the
   standard ring-algorithm cost model;
3. propagate loop multipliers: a collective inside a ``while`` body (our
   layer scan / microbatch scan) executes trip-count times.  Trip counts are
   recovered from the loop-condition's compare constant.

Two totals are returned: ``flat`` (each op once — used by the finite
difference probes) and ``weighted`` (loop-aware — used for the full scan
lowering).  tests/test_hlo_analysis.py checks both on hand-built modules.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f4e2m1fn": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns one dict; newer returns a one-element list of dicts
    (one per partition).  Callers always want the flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
# iota form: replica_groups=[G,n]<=[...] (optionally with T(perm)): n per group
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[[0-9,]+\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its body lines.

    Computation headers sit at column 0 and end with ``{``; instructions are
    indented; the closing ``}`` is at column 0.  (Metadata tables at the top
    of scheduled modules put ``{...}`` on one line — excluded by requiring
    the trailing ``{``.)
    """
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            s = line.rstrip()
            if not line.startswith(" ") and s.endswith("{") and "(" in s:
                name = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                name = name.lstrip("%")
                # strip a trailing parameter list glued to the name
                name = name.split("(")[0]
                cur = name
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    if _SRC_TGT_RE.search(line):
        return 2                       # collective-permute: pairwise hop
    return total_devices


def _op_bytes(op: str, out_bytes: int, n: int) -> int:
    """Per-device bytes moved, ring-algorithm model.

    out_bytes is the result-shape size per device.
      all-reduce       2(n-1)/n * size        (size = out)
      all-gather       (n-1)/n * out          (out is the gathered tensor)
      reduce-scatter   (n-1) * out            (input = n * out shards)
      all-to-all       (n-1)/n * out
      collective-permute  out
    """
    if n <= 1:
        return 0
    if op == "all-reduce":
        return int(2 * (n - 1) / n * out_bytes)
    if op == "all-gather":
        return int((n - 1) / n * out_bytes)
    if op == "reduce-scatter":
        return int((n - 1) * out_bytes)
    if op == "all-to-all":
        return int((n - 1) / n * out_bytes)
    return out_bytes   # collective-permute


# op spot + async variants; result type is everything left of the match
_COLL_RE = re.compile(
    r"\s(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")


@dataclass
class CollectiveReport:
    flat_bytes: int = 0
    weighted_bytes: int = 0
    counts: Dict[str, int] = field(default_factory=dict)
    weighted_counts: Dict[str, float] = field(default_factory=dict)
    by_comp: Dict[str, int] = field(default_factory=dict)

    def summary(self) -> Dict:
        return {"flat_bytes": self.flat_bytes,
                "weighted_bytes": self.weighted_bytes,
                "counts": dict(self.counts),
                "weighted_counts": dict(self.weighted_counts)}


def _trip_count(line: str, comps: Dict[str, List[str]], cond: str) -> int:
    """Loop trip count: XLA's known_trip_count when present, else the
    largest compare constant in the loop condition."""
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    trip = 1
    for cl in comps.get(cond, ()):
        for c in _CONST_RE.findall(cl):
            trip = max(trip, int(c))
    return trip


def _comp_multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (loop trip counts)."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry in mult:
        mult[entry] = 1.0
    # fixpoint over the (shallow) call graph
    for _ in range(8):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                w = _WHILE_RE.search(line)
                if w:
                    cond, body = w.group(1), w.group(2)
                    trip = _trip_count(line, comps, cond)
                    for tgt, factor in ((body, trip), (cond, trip)):
                        new = m * factor
                        if tgt in mult and mult[tgt] < new:
                            mult[tgt] = new
                            changed = True
                    continue
                c = _CALL_RE.search(line)
                if c:
                    for tgt in re.split(r",\s*", c.group(1)):
                        tgt = tgt.lstrip("%")
                        if tgt in mult and mult[tgt] < m:
                            mult[tgt] = m
                            changed = True
        if not changed:
            break
    return mult


def dissect(hlo: str, total_devices: int, top: int = 20):
    """Rank collectives by loop-weighted bytes, with op_name provenance.

    The per-op ``metadata={op_name=...}`` string names the jaxpr source
    (e.g. 'transpose(jvp(...))/dot_general'), which localizes each
    collective to model code — the §Perf hypothesis generator."""
    comps = split_computations(hlo)
    entry = ""
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
    mult = _comp_multipliers(comps, entry)
    rows = []
    meta_re = re.compile(r'op_name="([^"]*)"')
    for name, lines in comps.items():
        m = max(mult.get(name, 0.0), 1.0)
        for line in lines:
            c = _COLL_RE.search(line)
            if not c:
                continue
            op = c.group(1)
            eq = line.find("=")
            out_type = line[eq + 1:c.start()] if eq >= 0 else ""
            n = _group_size(line, total_devices)
            b = _op_bytes(op, shape_bytes(out_type), n)
            mm = meta_re.search(line)
            rows.append({
                "op": op, "bytes": b, "mult": m, "weighted": int(b * m),
                "group": n, "comp": name,
                "src": mm.group(1)[-120:] if mm else "",
            })
    rows.sort(key=lambda r: -r["weighted"])
    return rows[:top]


def collective_report(hlo: str, total_devices: int) -> CollectiveReport:
    comps = split_computations(hlo)
    entry = ""
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
    if not entry:
        entry = next(iter(comps), "")
    mult = _comp_multipliers(comps, entry)

    rep = CollectiveReport()
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        for line in lines:
            c = _COLL_RE.search(line)
            if not c:
                continue
            op = c.group(1)
            # the result type is everything between '=' and the op name
            eq = line.find("=")
            out_type = line[eq + 1:c.start()] if eq >= 0 else ""
            n = _group_size(line, total_devices)
            b = _op_bytes(op, shape_bytes(out_type), n)
            rep.flat_bytes += b
            rep.weighted_bytes += int(b * max(m, 1.0))
            rep.counts[op] = rep.counts.get(op, 0) + 1
            rep.weighted_counts[op] = rep.weighted_counts.get(op, 0.0) + \
                max(m, 1.0)
            rep.by_comp[name] = rep.by_comp.get(name, 0) + b
    return rep
