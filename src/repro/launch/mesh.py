"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization — the dry-run driver
must set XLA_FLAGS *before* the first jax call and smoke tests must keep
seeing one device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-mesh path and tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """Mesh over whatever devices exist (CPU tests: usually 1)."""
    n = len(jax.devices())
    model = model or 1
    return jax.make_mesh((n // model, model), ("data", "model"))
