import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run driver (deliverable e) + roofline extraction (g).

MUST be run as ``python -m repro.launch.dryrun`` — the XLA_FLAGS line above
executes before any other import so the 512 placeholder devices exist when
jax initializes.  Per (arch × shape × mesh) cell it:

1. builds abstract params/optimizer/batch specs (ShapeDtypeStruct only),
2. ``jax.jit(step).lower(...)`` then ``.compile()`` on the production mesh,
3. records ``memory_analysis()`` / ``cost_analysis()`` / the collective
   schedule parsed from the partitioned HLO,
4. optionally lowers the *unrolled L=2 probe* of the same cell so the
   roofline can separate fixed vs per-layer cost (cost_analysis counts a
   while body once; see DESIGN.md §8),
5. writes one JSON per cell under experiments/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all            # every non-skipped cell
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.distributed import sharding as dist
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_state, batch_entry, cache_specs,
                                default_microbatches, grad_dtype_for,
                                probe_config, skip_reason, state_shardings,
                                train_batch_specs)
from repro.models.config import SHAPES_BY_NAME
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime.steps import build_serve_steps, build_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# TPU v5e constants (task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _optimizer_for(cfg):
    return make_optimizer(cfg.optimizer, warmup_cosine(3e-4, 100, 10_000))


def kernel_dispatch_record(cfg, shape) -> Dict[str, Any]:
    """Resolve the cell's kernel variants through the artifact DispatchCache.

    This is the dry-run view of the offline/online split: with compiled
    artifacts present (``REPRO_ARTIFACT_DIR`` / ``./artifacts``) every entry
    is a table lookup; without them it is a one-time in-process build.  The
    record lands in the cell JSON so the roofline can tie collective/compute
    numbers to the exact kernel variants the TPU build would instantiate."""
    from repro.artifacts.dispatch import get_default_cache
    from repro.kernels.ops import FAMILIES, select
    from repro.core.params import TPU_V5E
    rec: Dict[str, Any] = {}
    queries = {
        "flash_attention": {"SQ": shape.seq_len, "HD": cfg.hd},
        "matmul": {"M": shape.seq_len, "N": cfg.d_ff or 4 * cfg.d_model,
                   "K": cfg.d_model},
    }
    for fam_name, data in queries.items():
        if fam_name not in FAMILIES:
            continue
        try:
            cand = select(fam_name, data, TPU_V5E)
        except ValueError as e:
            rec[fam_name] = {"status": "INFEASIBLE", "error": str(e)}
            continue
        rec[fam_name] = {
            "data": dict(data),
            "plan": cand.plan.describe(),
            "assignment": dict(cand.assignment),
            "score": cand.score,
        }
    rec["cache"] = get_default_cache().stats.as_dict()
    return rec


def _np(x):
    return None if x is None else float(x)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               probe_layers: Optional[int] = None,
               keep_hlo: bool = False,
               overrides: Optional[Dict[str, Any]] = None,
               microbatches: Optional[int] = None,
               zero2_acc: bool = False,
               kernel_table: bool = False,
               tag: str = "") -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline-relevant record.

    ``overrides`` patches ModelConfig fields (perf_flags, remat, ...);
    ``microbatches``/``zero2_acc`` patch the train-step build — together
    these are the §Perf hillclimb knobs."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "probe_layers": probe_layers,
    }
    skip = skip_reason(cfg, shape)
    if skip:
        rec["status"] = "SKIP"
        rec["skip_reason"] = skip
        return rec

    if probe_layers is not None:
        cfg = probe_config(cfg, probe_layers)
        # probes unroll every loop so cost_analysis sees each body
        from repro.models import layers as model_layers
        model_layers.set_unroll_inner(True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rules = dist.rules_for(cfg, mesh)
    t0 = time.time()

    with mesh, dist.use_mesh_rules(mesh, rules):
        if shape.kind == "train":
            opt = _optimizer_for(cfg)
            params_sds, axes, opt_sds = abstract_state(cfg, opt)
            p_sh, o_sh, _ = state_shardings(cfg, mesh, params_sds, axes,
                                            opt_sds)
            batch_sds, batch_sh = train_batch_specs(cfg, shape, mesh)
            # probes use one microbatch: the roofline reconstruction is
            # total = mb_real x (fixed + L x per_layer); see benchmarks/roofline
            mb = 1 if probe_layers is not None else \
                (microbatches or default_microbatches(cfg, shape, mesh))
            rec["microbatches"] = mb
            acc_sh = None
            if zero2_acc:
                from repro.launch.specs import _zero1_one
                acc_sh = jax.tree.map(
                    lambda sh, sds: _zero1_one(sh, sds, mesh),
                    p_sh, params_sds,
                    is_leaf=lambda t: hasattr(t, "spec"))
                rec["zero2_acc"] = True
            step_fn = build_train_step(
                cfg, opt, microbatches=mb, grad_dtype=grad_dtype_for(cfg),
                unroll=probe_layers is not None, acc_shardings=acc_sh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, batch_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(
                params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        else:
            params_sds, axes, _ = abstract_state(cfg, None)
            p_sh, _, _ = state_shardings(cfg, mesh, params_sds, axes, None)
            GB, S = shape.global_batch, shape.seq_len
            from jax.sharding import NamedSharding, PartitionSpec as P
            bentry = batch_entry(mesh, GB)
            prefill_step, decode_step = build_serve_steps(
                cfg, unroll=probe_layers is not None)
            if shape.kind == "prefill":
                c_sds, c_sh = cache_specs(cfg, GB, S, mesh)
                tok = jax.ShapeDtypeStruct((GB, S), jnp.int32)
                tok_sh = NamedSharding(mesh, P(bentry, None))
                if cfg.encoder is not None:
                    enc_sds = jax.ShapeDtypeStruct(
                        (GB, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16)
                    enc_sh = NamedSharding(mesh, P(bentry, None, None))
                    fn = (lambda p, t, c, e:
                          prefill_step(p, t, c, enc_embeds=e))
                    jitted = jax.jit(
                        fn, in_shardings=(p_sh, tok_sh, c_sh, enc_sh),
                        out_shardings=(None, c_sh))
                    lowered = jitted.lower(params_sds, tok, c_sds, enc_sds)
                else:
                    jitted = jax.jit(
                        prefill_step,
                        in_shardings=(p_sh, tok_sh, c_sh),
                        out_shardings=(None, c_sh))
                    lowered = jitted.lower(params_sds, tok, c_sds)
            else:  # decode
                c_sds, c_sh = cache_specs(cfg, GB, S, mesh)
                tok = jax.ShapeDtypeStruct((GB, 1), jnp.int32)
                tok_sh = NamedSharding(mesh, P(bentry, None))
                idx = jax.ShapeDtypeStruct((), jnp.int32)
                jitted = jax.jit(
                    decode_step,
                    in_shardings=(p_sh, tok_sh, c_sh, None),
                    out_shardings=(None, c_sh),
                    donate_argnums=(2,))
                lowered = jitted.lower(params_sds, tok, c_sds, idx)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    cost = hlo_analysis.cost_analysis_dict(compiled)
    rec["status"] = "OK"
    rec["devices"] = n_dev
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "generated_code_bytes": getattr(
            mem, "generated_code_size_in_bytes", None),
    }
    rec["cost"] = {
        "flops": _np(cost.get("flops")),
        "bytes_accessed": _np(cost.get("bytes accessed")),
        "transcendentals": _np(cost.get("transcendentals")),
    }
    hlo = compiled.as_text()
    rep = hlo_analysis.collective_report(hlo, n_dev)
    rec["collectives"] = rep.summary()
    if kernel_table:
        rec["kernel_dispatch"] = kernel_dispatch_record(cfg, shape)
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(os.path.join(
                OUT_DIR, f"{arch}_{shape_name}_{rec['mesh']}"
                f"{'_probe' + str(probe_layers) if probe_layers else ''}"
                f"{('_' + tag) if tag else ''}.hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probe_layers: Optional[int], keep_hlo: bool,
             overrides: Optional[Dict[str, Any]] = None,
             microbatches: Optional[int] = None,
             zero2_acc: bool = False,
             kernel_table: bool = False,
             tag: str = "") -> Dict[str, Any]:
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         probe_layers=probe_layers, keep_hlo=keep_hlo,
                         overrides=overrides, microbatches=microbatches,
                         zero2_acc=zero2_acc, kernel_table=kernel_table,
                         tag=tag)
    except Exception as e:                                    # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "probe_layers": probe_layers,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    os.makedirs(OUT_DIR, exist_ok=True)
    fname = (f"{arch}_{shape_name}_{rec['mesh']}"
             f"{'_probe' + str(probe_layers) if probe_layers else ''}"
             f"{('_' + tag) if tag else ''}")
    with open(os.path.join(OUT_DIR, fname + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probe-layers", type=int, default=None)
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--all", action="store_true")
    # §Perf hillclimb knobs
    ap.add_argument("--perf-flags", type=str, default=None,
                    help="comma list: attn_q_heads,rope_compute,probs_bf16")
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--param-dtype", type=str, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--zero2-acc", action="store_true")
    ap.add_argument("--kernel-table", action="store_true",
                    help="record per-family kernel dispatch (artifact cache) "
                         "in the cell JSON")
    ap.add_argument("--tag", type=str, default="",
                    help="suffix for the output JSON (variant runs)")
    args = ap.parse_args()

    overrides: Dict[str, Any] = {}
    if args.perf_flags is not None:
        overrides["perf_flags"] = tuple(
            f for f in args.perf_flags.split(",") if f)
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.param_dtype is not None:
        overrides["param_dtype"] = args.param_dtype

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.probe_layers,
                       args.keep_hlo, overrides=overrides or None,
                       microbatches=args.microbatches,
                       zero2_acc=args.zero2_acc,
                       kernel_table=args.kernel_table, tag=args.tag)
        status = rec["status"]
        extra = ""
        if status == "OK":
            extra = (f" compile={rec['compile_s']}s "
                     f"flops={rec['cost']['flops']:.3g} "
                     f"coll={rec['collectives']['weighted_bytes']:.3g}B")
        elif status == "FAIL":
            extra = " " + rec["error"][:160]
        print(f"[{status}] {arch} {shape} {rec['mesh']}"
              f"{' probe' + str(args.probe_layers) if args.probe_layers else ''}"
              f"{extra}", flush=True)


if __name__ == "__main__":
    main()
