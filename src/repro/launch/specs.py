"""Abstract input/state specs per (arch × shape) cell — no allocation.

Everything here returns ``jax.ShapeDtypeStruct`` trees plus matching
``NamedSharding`` trees, so ``jax.jit(...).lower(...)`` can compile the full
production configuration without materializing a single parameter
(1T-parameter models lower fine on the CPU container).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as dist
from ..models import cache_spec_axes, init_cache, init_model
from ..models.config import ModelConfig, ShapeConfig, SHAPES_BY_NAME
from ..optim import Optimizer

PyTree = Any

PATCH_TOKENS = 256        # chameleon stub: VQ patches fused at the front


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Cell-skip policy (recorded, never silent)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("long-context policy: pure full-attention arch has no "
                "sub-quadratic path at 524k (DESIGN.md §7)")
    return None


def probe_config(cfg: ModelConfig, layers: int) -> ModelConfig:
    """Same arch with a reduced *layer count only* (roofline probes)."""
    kw: Dict[str, Any] = {"layers": layers}
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, layers=layers)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Abstract model/optimizer state
# ---------------------------------------------------------------------------

def abstract_state(cfg: ModelConfig, optimizer: Optional[Optimizer] = None
                   ) -> Tuple[PyTree, PyTree, Optional[PyTree]]:
    """(params_sds, axes, opt_sds) via eval_shape — zero allocation."""
    captured: Dict[str, Any] = {}

    def f(key):
        p, a = init_model(key, cfg)
        captured["axes"] = a
        return p

    params_sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    axes = captured["axes"]
    opt_sds = None
    if optimizer is not None:
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
    return params_sds, axes, opt_sds


def state_shardings(cfg: ModelConfig, mesh: Mesh, params_sds: PyTree,
                    axes: PyTree, opt_sds: Optional[PyTree] = None
                    ) -> Tuple[PyTree, Optional[PyTree], Dict]:
    rules = dist.rules_for(cfg, mesh)
    with dist.use_mesh_rules(mesh, rules):
        p_sh = dist.shardings_for(axes, params_sds, mesh, rules)
    opt_sh = None
    if opt_sds is not None:
        # each optimizer-state leaf inherits its parameter's sharding,
        # then gets the ZeRO-1 extension over the batch axes.
        opt_sh = _opt_shardings(p_sh, opt_sds)
        opt_sh = jax.tree.map(
            lambda sh, sds: _zero1_one(sh, sds, mesh),
            opt_sh, opt_sds,
            is_leaf=lambda t: isinstance(t, NamedSharding))
    return p_sh, opt_sh, rules


def _opt_shardings(param_shardings: PyTree, opt_sds: PyTree) -> PyTree:
    """Give each optimizer-state leaf its parameter's sharding when the
    shapes match, else replicate (factored Adafactor vectors)."""
    flat_p, _ = jax.tree_util.tree_flatten_with_path(param_shardings,
                                                     is_leaf=lambda t: isinstance(t, NamedSharding))
    by_path = {tuple(str(k) for k in path): sh for path, sh in flat_p}

    def locate(path):
        """Match an opt-state path to its param path by dropping the
        state-prefix keys (m/v/f) and trailing state keys (v/vr/vc)."""
        keys = [str(k) for k in path]
        keys = [k for k in keys if k not in ("['m']", "['v']", "['f']",
                                             "['vr']", "['vc']")]
        return tuple(keys)

    flat_o, treedef = jax.tree_util.tree_flatten_with_path(opt_sds)
    out = []
    for path, sds in flat_o:
        sh = by_path.get(locate(path))
        if sh is not None and len(sh.spec) <= len(sds.shape):
            # same-rank state (m/v): reuse; factored vectors keep a prefix
            spec = tuple(sh.spec)[:len(sds.shape)]
            mesh = sh.mesh
            out.append(NamedSharding(mesh, P(*spec)))
        elif sh is not None:
            out.append(NamedSharding(sh.mesh, P()))
        else:
            raise KeyError(f"no param sharding for opt leaf {path}")
    return jax.tree_util.tree_unflatten(treedef, out)


def _zero1_one(sh: NamedSharding, sds, mesh: Mesh) -> NamedSharding:
    """ZeRO-1: extend one state leaf's sharding over the batch axes."""
    batch = dist.batch_axes(mesh)
    if not batch:
        return sh
    import numpy as np
    denom = int(np.prod([mesh.shape[a] for a in batch]))
    spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
    used = set()
    for e in spec:
        for a in ((e,) if isinstance(e, str) else (e or ())):
            used.add(a)
    if any(a in used for a in batch):
        return sh
    best, best_size = None, 0
    for i, (e, size) in enumerate(zip(spec, sds.shape)):
        if e is None and size % denom == 0 and size > best_size:
            best, best_size = i, size
    if best is not None:
        spec[best] = batch if len(batch) > 1 else batch[0]
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# Input specs per shape kind
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def batch_entry(mesh: Mesh, global_batch: int):
    """Mesh axes for the batch dim, or None when not divisible (batch=1
    long-context decode leaves the data axis idle — recorded honestly)."""
    import numpy as np
    axes = dist.batch_axes(mesh)
    if not axes:
        return None
    prod = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % prod != 0:
        # try the largest divisible suffix (e.g. just 'data')
        for k in range(len(axes) - 1, 0, -1):
            sub = axes[-k:]
            if global_batch % int(np.prod([mesh.shape[a] for a in sub])) == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                      ) -> Tuple[Dict, Dict]:
    GB, S = shape.global_batch, shape.seq_len
    batch = dist.batch_axes(mesh)
    bspec = P(batch if len(batch) > 1 else batch[0] if batch else None)
    sds = {
        "tokens": jax.ShapeDtypeStruct((GB, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((GB, S), jnp.int32),
    }
    sh = {
        "tokens": NamedSharding(mesh, P(*bspec, None)),
        "labels": NamedSharding(mesh, P(*bspec, None)),
    }
    if cfg.encoder is not None:
        sds["enc_embeds"] = jax.ShapeDtypeStruct(
            (GB, cfg.encoder.seq_len, cfg.d_model), _dtype(cfg))
        sh["enc_embeds"] = NamedSharding(mesh, P(*bspec, None, None))
    elif cfg.frontend == "stub":
        sds["patch_embeds"] = jax.ShapeDtypeStruct(
            (GB, PATCH_TOKENS, cfg.d_model), _dtype(cfg))
        sh["patch_embeds"] = NamedSharding(mesh, P(*bspec, None, None))
    return sds, sh


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh
                ) -> Tuple[PyTree, PyTree]:
    sds = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    rules = dist.rules_for(cfg, mesh)
    axes = cache_spec_axes(cfg)
    with dist.use_mesh_rules(mesh, rules):
        sh = {k: NamedSharding(
            mesh, dist.spec_for(axes[k], rules, tuple(sds[k].shape)))
            for k in sds}
    return sds, sh


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                         ) -> int:
    """Keep ~one 4k-token row per device per microbatch."""
    import numpy as np
    batch = dist.batch_axes(mesh)
    shards = int(np.prod([mesh.shape[a] for a in batch])) if batch else 1
    rows_per_dev = max(1, shape.global_batch // shards)
    rows_per_mb = max(1, 4096 // shape.seq_len)
    return max(1, rows_per_dev // rows_per_mb)


def grad_dtype_for(cfg: ModelConfig):
    """bf16 accumulators for the 1T MoE (f32 would not fit; DESIGN.md §6)."""
    return jnp.bfloat16 if cfg.name == "kimi-k2-1t-a32b" else jnp.float32
