"""Serving launcher: paged continuous-batching engine over a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 12
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
        --block-size 16 --prefill-chunk 32 --num-blocks 64   # KV-pool knobs
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import init_model
from repro.obs import FlightRecorder, install
from repro.plans import PlanStore
from repro.runtime import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU-scale; default is smoke)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV cache block size in token positions "
                         "(joins the kernel-dispatch bucket keys)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks incl. the reserved garbage "
                         "block (default: every slot can hold max-len; "
                         "smaller exercises admission waits + preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max tokens prefilled per engine tick (chunked "
                         "prefill; tails quantize to powers of two)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="map page-aligned prompt blocks already resident "
                         "in the pool (refcounted, copy-on-write) instead "
                         "of re-prefilling them; auto-disabled for "
                         "SSM-bearing configs")
    ap.add_argument("--async-depth", type=int, default=1,
                    help="engine pipeline depth: 1 = synchronous, 2 = plan "
                         "tick t+1 on the host while the device executes "
                         "tick t (commit barrier before the next dispatch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-kernels", action="store_true",
                    help="pre-resolve kernel-variant dispatch at engine "
                         "start (uses a shipped serve-plan artifact when "
                         "one matches, else compiled artifacts/online "
                         "warm-up)")
    ap.add_argument("--plan-dir", default=None,
                    help="artifact root holding serve-plan artifacts "
                         "(scripts/plan_artifacts.py output; default: "
                         "$REPRO_ARTIFACT_DIR or ./artifacts)")
    ap.add_argument("--strict-plans", action="store_true",
                    help="refuse to start from a serve plan whose recorded "
                         "dispatch-table digests no longer match this "
                         "host's tables (default: warn and fall back to "
                         "online warm-up)")
    ap.add_argument("--monitor", action="store_true",
                    help="adaptive loop: probe frozen kernel picks with "
                         "cheap wall-clock timings during traffic and "
                         "hot-swap any pick measurement persistently "
                         "contradicts (requires --warm-kernels)")
    ap.add_argument("--monitor-window", type=int, default=8,
                    help="probes per decision window")
    ap.add_argument("--monitor-every", type=int, default=4,
                    help="engine ticks between probes")
    ap.add_argument("--swap-threshold", type=float, default=1.25,
                    help="challenger must beat the incumbent median by this "
                         "ratio for a window to disagree")
    ap.add_argument("--swap-patience", type=int, default=2,
                    help="consecutive disagreeing windows before a hot-swap")
    ap.add_argument("--degrade", action="store_true",
                    help="graceful degradation: a failed kernel call demotes "
                         "the frozen pick down the candidate ranking and "
                         "retries once; a second failure preempts the "
                         "affected sequences (recompute) instead of killing "
                         "the engine")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue: submissions beyond this "
                         "many waiting requests are shed with a structured "
                         "queue_full error + retry hint (default: unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: queued or running requests "
                         "older than this are cancelled with a structured "
                         "deadline error (default: none)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="flight recorder: write the run's provenance "
                         "trace (scheduling decisions, dispatch "
                         "resolutions, swaps/demotions, fault firings) as "
                         "JSONL to PATH; feed it to scripts/trace_report.py")
    ap.add_argument("--trace-sample", type=int, default=0, metavar="N",
                    help="with --trace: sample 1-in-N hits of the frozen "
                         "warm_callable lane as dispatch_decision records "
                         "(default 0 = the warm lane stays uncounted)")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="flight-recorder ring size in events; the oldest "
                         "age out first and are counted as dropped")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.encoder is not None:
        raise SystemExit("enc-dec serving demo not wired for CLI; "
                         "see tests/test_serving.py")
    recorder = None
    if args.trace:
        recorder = FlightRecorder(capacity=args.trace_capacity,
                                  sample_frozen_every=args.trace_sample)
        install(recorder)
    params, _ = init_model(jax.random.PRNGKey(args.seed), cfg)
    plan_store = PlanStore(args.plan_dir) if args.plan_dir else None
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len, page_size=args.block_size,
                      num_blocks=args.num_blocks,
                      prefill_chunk=args.prefill_chunk,
                      prefix_sharing=args.prefix_sharing,
                      async_depth=args.async_depth,
                      warm_kernels=args.warm_kernels,
                      plan_store=plan_store,
                      strict_plans=args.strict_plans,
                      monitor=args.monitor,
                      monitor_window=args.monitor_window,
                      monitor_every=args.monitor_every,
                      swap_threshold=args.swap_threshold,
                      swap_patience=args.swap_patience,
                      degrade=args.degrade,
                      max_queue=args.max_queue,
                      deadline_ms=args.deadline_ms)
    if eng.kernel_plan:
        print(f"warm-up: {len(eng.kernel_plan)} kernel picks resolved "
              f"(final provenance reported after the run)")

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab, plen), max_new=args.max_new)
    done = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    for r in done[:4]:
        if r.error is not None:
            print(f"req {r.rid}: [{r.error.code}] {r.error}")
        else:
            print(f"req {r.rid}: {r.out}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    # the unified registry replaces the old scattered stats prints; the
    # kernel report reads the *current* frozen plan, so picks changed by
    # a monitor hot-swap or a degradation demote carry their live
    # provenance, not the warm-up snapshot
    reg = eng.registry()
    print(reg.summary_line())
    for line in reg.kernel_report():
        print(line)
    if eng.monitor is not None:
        for ev in eng.monitor.events:
            print(f"swap {ev.describe()}")
    for ev in eng.degrade_events:
        print(f"degrade {ev.describe()}")
    if recorder is not None:
        with open(args.trace, "w") as fh:
            fh.write(recorder.export_jsonl())
        print(f"trace: {recorder.emitted} events "
              f"({recorder.dropped} dropped) -> {args.trace}")


if __name__ == "__main__":
    main()
