"""Parametric Mamba-2 SSD chunked scan (state-space duality, arXiv 2405.21060).

The SSD insight: a selective-state-space recurrence over a chunk of length C
equals a (C×C) masked "attention" matmul (intra-chunk, MXU-friendly) plus a
rank-`state` carry between chunks.  Chunk length is the program parameter the
comprehensive tree optimizes — exactly the paper's granularity knob, with VMEM
as the binding resource (the (C×C) score tile + state carry must fit).

Grid layout: (heads, n_chunks) with the chunk axis innermost; TPU executes the
grid sequentially, so the inter-chunk state lives in VMEM scratch across grid
steps (same mechanism as the k-accumulation in matmul).
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin


def ssd_chunk(xc, ac, bc, cc, S_prev):
    """One chunk of the SSD recurrence in matmul form (shared with models/).

    xc: (C, hd)  ac: (C,)  bc/cc: (C, state)  S_prev: (state, hd)
    Returns (y: (C, hd), S_new: (state, hd)).  All f32.
    """
    C = xc.shape[0]
    la = jnp.log(ac)                                   # a in (0, 1)
    cum = jnp.cumsum(la)                               # (C,)
    # L[t, i] = exp(cum[t] - cum[i]) for i <= t else 0; mask BEFORE exp so the
    # (positive) upper-triangle differences can never overflow to inf.
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    L = jnp.exp(jnp.where(row >= col, diff, -jnp.inf))
    scores = (cc @ bc.T) * L                           # (C, C)
    y_intra = scores @ xc                              # (C, hd)
    y_inter = (cc * jnp.exp(cum)[:, None]) @ S_prev    # (C, hd)
    a_tot = jnp.exp(cum[-1])
    w = jnp.exp(cum[-1] - cum)                         # decay to chunk end
    S_new = a_tot * S_prev + (bc * w[:, None]).T @ xc  # (state, hd)
    return y_intra + y_inter, S_new


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, nc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xc = x_ref[:, 0, :].astype(jnp.float32)
    ac = a_ref[:, 0].astype(jnp.float32)
    bc = b_ref[:, 0, :].astype(jnp.float32)
    cc = c_ref[:, 0, :].astype(jnp.float32)
    y, S_new = ssd_chunk(xc, ac, bc, cc, state_ref[...])
    state_ref[...] = S_new
    y_ref[:, 0, :] = y.astype(y_ref.dtype)


def pallas_ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                    *, chunk: int, interpret: bool = False) -> jax.Array:
    """x: (seq, heads, hd); a: (seq, heads); b,c: (seq, heads, state)."""
    seq, heads, hd = x.shape
    state = b.shape[-1]
    ck = min(chunk, seq)
    seq_p = -(-seq // ck) * ck
    # pad with a=1 (identity decay), x=0 so padding contributes nothing
    x = jnp.pad(x, ((0, seq_p - seq), (0, 0), (0, 0)))
    a = jnp.pad(a, ((0, seq_p - seq), (0, 0)), constant_values=1.0)
    b = jnp.pad(b, ((0, seq_p - seq), (0, 0), (0, 0)))
    c = jnp.pad(c, ((0, seq_p - seq), (0, 0), (0, 0)))
    nc = seq_p // ck

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(heads, nc),
        in_specs=[
            pl.BlockSpec((ck, 1, hd), lambda h, j: (j, h, 0)),
            pl.BlockSpec((ck, 1), lambda h, j: (j, h)),
            pl.BlockSpec((ck, 1, state), lambda h, j: (j, h, 0)),
            pl.BlockSpec((ck, 1, state), lambda h, j: (j, h, 0)),
        ],
        out_specs=pl.BlockSpec((ck, 1, hd), lambda h, j: (j, h, 0)),
        out_shape=jax.ShapeDtypeStruct((seq_p, heads, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((state, hd), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y[:seq]


class SsdScanFamily(CachedInstantiationMixin):
    name = "ssd_scan"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"granularity_level": 0},
            program_params={
                "chunk": ParamDomain("chunk", (64, 128, 256), align=8),
            },
        )

    def counters(self) -> Sequence[Counter]:
        return [
            resource("vmem_bytes", "V", ("reduce_chunk",),
                     "x/b/c blocks + (C,C) score tile + state carry"),
            resource("vreg_pressure", "G", ()),
            performance("occupancy", "P_occ", ("reduce_chunk",)),
        ]

    def strategies(self) -> Sequence[Strategy]:
        def reduce_chunk(plan: KernelPlan):
            if plan.flags.get("granularity_level", 0) >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce chunk")
            p.program_params["chunk"] = ParamDomain("chunk", (64,), align=8)
            return p

        return [Strategy("reduce_chunk", reduce_chunk)]

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        C, hd, st = V("chunk"), V("HD"), V("STATE")
        one = Poly.const(1)
        if counter == "vmem_bytes":
            blocks = 2 * 4 * (C * hd + C + 2 * C * st)     # dbl-buffered f32
            tile = 4 * (C * C + st * hd + C * hd)
            return blocks + tile, one
        if counter == "vreg_pressure":
            return C * C / (8 * 128) + st * hd / (8 * 128), one
        if counter == "occupancy":
            return V("CORES") * C, V("SQ")
        raise KeyError(counter)

    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        C = v["chunk"]
        sq = v.get("SQ", 4096)
        # bigger chunks amortize the state carry but grow the C^2 tile
        mxu_fill = min(1.0, C / 128)
        carry_amort = C / (C + v.get("STATE", 64))
        return mxu_fill * carry_amort * min(1.0, sq / C / 8)

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        return functools.partial(pallas_ssd_scan,
                                 chunk=int(assignment["chunk"]),
                                 interpret=interpret)


FAMILY = SsdScanFamily()
