"""Parametric Pallas kernels, each driven by the comprehensive tree.

Families: matmul (paper Fig. 3/4), matadd (Fig. 1/2), jacobi1d (Fig. 7),
transpose (Fig. 8), flash_attention and ssd_scan (LM substrate hot-spots).
Each module provides the pl.pallas_call kernel(s) + a FamilySpec; ``ops``
holds the jit'd public wrappers and ``ref`` the pure-jnp oracles.
"""
from . import ref  # noqa: F401
