"""Parametric matrix addition — the paper's introductory example (Fig. 1/2).

The comprehensive tree for this family reproduces the paper's two-case
discussion: the source plan has grain s=2 (each step writes the j and j+N/2
halves, register estimate 14); the granularity-reduction strategy yields the
single-element variant (register estimate 10), giving exactly

    C1: { B0*B1 <= T,  14 <= R }          -> K1 (grain 2)
    C2: { B0*B1 <= T,  10 <= R < 14 }     -> K2 (grain 1)

with TPU names: T -> lane-tile budget, R -> G (vreg budget).
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin

DT = 4  # f32 bytes


def _add_kernel(a_ref, b_ref, o_ref, *, s: int, bn: int):
    for t in range(s):                       # paper's grain (Fig. 2 K1: s=2)
        sl = slice(t * bn, (t + 1) * bn)
        o_ref[:, sl] = a_ref[:, sl] + b_ref[:, sl]


def pallas_matadd(a: jax.Array, b: jax.Array, *, bm: int, bn: int, s: int,
                  interpret: bool = False) -> jax.Array:
    M, N = a.shape
    bn_tot = bn * s
    Mp, Np = -(-M // bm) * bm, -(-N // bn_tot) * bn_tot
    a = jnp.pad(a, ((0, Mp - M), (0, Np - N)))
    b = jnp.pad(b, ((0, Mp - M), (0, Np - N)))
    out = pl.pallas_call(
        functools.partial(_add_kernel, s=s, bn=bn),
        grid=(Mp // bm, Np // bn_tot),
        in_specs=[pl.BlockSpec((bm, bn_tot), lambda i, j: (i, j))] * 2,
        out_specs=pl.BlockSpec((bm, bn_tot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:M, :N]


class MataddFamily(CachedInstantiationMixin):
    name = "matadd"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"granularity_level": 0, "cse_level": 0},
            program_params={
                "bm": ParamDomain("bm", (8, 16, 32, 64, 128, 256), align=8),
                "bn": ParamDomain("bn", (128, 256, 512), align=128),
                "s": ParamDomain("s", (2,)),     # paper source: two halves
            },
        )

    def counters(self) -> Sequence[Counter]:
        return [
            resource("lane_tile", "T", (),
                     "2D tile area per grid step (paper: threads/block)"),
            resource("vreg_pressure", "G", ("reduce_granularity", "cse_1"),
                     "paper's register estimate: 14 at s=2, 10 at s=1"),
            resource("vmem_bytes", "V", ("reduce_granularity",)),
            performance("occupancy", "P_occ", ("reduce_granularity",)),
        ]

    def strategies(self) -> Sequence[Strategy]:
        def reduce_granularity(plan: KernelPlan):
            if plan.flags.get("granularity_level", 0) >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce granularity")
            p.program_params["s"] = ParamDomain("s", (1,))
            return p

        def cse(plan: KernelPlan):
            if plan.flags.get("cse_level", 0) >= 1:
                return None
            return plan.with_flag("cse_level", 1, "CSE on index arithmetic")

        return [Strategy("reduce_granularity", reduce_granularity),
                Strategy("cse_1", cse)]

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        bm, bn, s = V("bm"), V("bn"), V("s")
        one = Poly.const(1)
        if counter == "lane_tile":
            return bm * bn * s, one
        if counter == "vreg_pressure":
            # mirror the paper's IR estimates: grain 2 -> 14, grain 1 -> 10
            g = plan.flags.get("granularity_level", 0)
            c = plan.flags.get("cse_level", 0)
            base = 14 if g == 0 else 10
            return Poly.const(base - 2 * c), one
        if counter == "vmem_bytes":
            return 3 * DT * bm * bn * s * 2, one       # a,b,o double-buffered
        if counter == "occupancy":
            return V("CORES") * bm * bn * s, V("M") * V("N")
        raise KeyError(counter)

    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        import math
        bm, bn, s = v["bm"], v["bn"], v["s"]
        M = v.get("M", 4096); N = v.get("N", 4096)
        lane = v.get("LANE", 128)
        fill = min(1.0, bm / 8) * min(1.0, bn / lane)
        waves = (math.ceil(M / bm) * math.ceil(N / (bn * s))) \
            / max(1, v.get("CORES", 1))
        return fill * min(1.0, waves) * min(1.0, (bm * bn * s) / 65536)

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        return functools.partial(
            pallas_matadd, bm=int(assignment["bm"]), bn=int(assignment["bn"]),
            s=int(assignment["s"]), interpret=interpret)


FAMILY = MataddFamily()
