"""Parametric 1D Jacobi stencil (paper Fig. 7, Table 2).

The comprehensive tree reproduces the paper's three cases:

  case 1:  2·s·B + 2 <= Z_B           cache(a) + grain s      (VMEM staged)
  case 2:  2·B + 2 <= Z_B < 2·s·B+2   cache(a) + grain 1
  case 3:  Z_B < 2·B + 2              no cache

One time-iteration is one kernel launch (as in the paper, where the t-loop is
outside meta_schedule).  The vector lives as a (1, n) 2D array so the lane
dimension carries the stencil; each grid step produces a (1, B·s) output block
from a (1, B·s+2) halo window read out of the full (VMEM-resident) input row.
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin

DT = 4


def _jacobi_kernel_cached(x_ref, o_ref, scratch_ref, *, bs: int):
    i = pl.program_id(0)
    base = i * bs
    # stage the halo window (paper: cache(a) -> __shared__), then compute
    scratch_ref[...] = x_ref[:, pl.dslice(base, bs + 2)]
    w = scratch_ref[...]
    o_ref[...] = (w[:, :-2] + w[:, 1:-1] + w[:, 2:]) / 3


def _jacobi_kernel_uncached(x_ref, o_ref, *, bs: int):
    i = pl.program_id(0)
    base = i * bs
    left = x_ref[:, pl.dslice(base, bs)]
    mid = x_ref[:, pl.dslice(base + 1, bs)]
    right = x_ref[:, pl.dslice(base + 2, bs)]
    o_ref[...] = (left + mid + right) / 3


def pallas_jacobi1d(x: jax.Array, steps: int, *, B: int, s: int,
                    cached: bool = True, interpret: bool = False
                    ) -> jax.Array:
    """x: 1D array; fixed boundaries; ``steps`` time iterations."""
    (n,) = x.shape
    inner = n - 2
    bs = B * s
    n_blocks = -(-inner // bs)
    pad = n_blocks * bs - inner
    row = jnp.pad(x, (0, pad))[None, :]                    # (1, n+pad)

    if cached:
        kern = pl.pallas_call(
            functools.partial(_jacobi_kernel_cached, bs=bs),
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec((1, n + pad), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((1, bs), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, n_blocks * bs), x.dtype),
            scratch_shapes=[pltpu.VMEM((1, bs + 2), x.dtype)],
            interpret=interpret,
        )
    else:
        kern = pl.pallas_call(
            functools.partial(_jacobi_kernel_uncached, bs=bs),
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec((1, n + pad), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((1, bs), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, n_blocks * bs), x.dtype),
            interpret=interpret,
        )

    for _ in range(steps):                                  # paper's t-loop
        interior = kern(row)[0, :inner]
        row = row.at[0, 1:1 + inner].set(interior)
    return row[0, :n]


class Jacobi1dFamily(CachedInstantiationMixin):
    name = "jacobi1d"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"vmem_cache": True, "granularity_level": 0},
            program_params={
                "B": ParamDomain("B", (128, 256, 512, 1024), align=128),
                "s": ParamDomain("s", (1, 2, 4, 8)),
            },
        )

    def counters(self) -> Sequence[Counter]:
        return [
            resource("vmem_bytes", "V", ("reduce_granularity", "uncache"),
                     "paper: 2sB+2 shared words (Z_B)"),
            resource("vreg_pressure", "G", (),
                     "paper: 9 <= R_B in all three cases"),
            performance("occupancy", "P_occ", ("reduce_granularity",)),
        ]

    def strategies(self) -> Sequence[Strategy]:
        def reduce_granularity(plan: KernelPlan):
            if plan.flags.get("granularity_level", 0) >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce granularity")
            p.program_params["s"] = ParamDomain("s", (1,))
            return p

        def uncache(plan: KernelPlan):
            if not plan.flags.get("vmem_cache", True):
                return None
            return plan.with_flag("vmem_cache", False, "drop VMEM staging")

        return [Strategy("reduce_granularity", reduce_granularity),
                Strategy("uncache", uncache)]

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        B, s = V("B"), V("s")
        one = Poly.const(1)
        if counter == "vmem_bytes":
            if plan.flags.get("vmem_cache", True):
                # paper's 2sB+2 words, in bytes (+ the output block)
                return DT * (2 * B * s + 2) + DT * B * s, one
            return DT * (2 * B + 2), one
        if counter == "vreg_pressure":
            return Poly.const(9), one
        if counter == "occupancy":
            return V("CORES") * B * s, V("N"),
        raise KeyError(counter)

    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        import math
        B, s = v["B"], v["s"]
        N = v.get("N", 1 << 15)
        lane = v.get("LANE", 128)
        fill = min(1.0, B / lane)
        waves = math.ceil(N / (B * s)) / max(1, v.get("CORES", 1))
        halo_overhead = (B * s) / (B * s + 2)
        return fill * min(1.0, waves) * halo_overhead

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        return functools.partial(
            pallas_jacobi1d, B=int(assignment["B"]), s=int(assignment["s"]),
            cached=bool(plan.flags.get("vmem_cache", True)),
            interpret=interpret)


FAMILY = Jacobi1dFamily()
