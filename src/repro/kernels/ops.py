"""Public jit'd wrappers: every call goes through the comprehensive tree.

``impl`` resolution:
  "pallas"  — instantiate the selected leaf's Pallas kernel (TPU target; on
              CPU pass ``interpret=True``, which tests do).
  "xla"     — the pure-jnp oracle path (used by the model stack on the CPU
              container and by the dry-run, where Pallas cannot lower).
  "auto"    — pallas on TPU backends, xla elsewhere.

The *selection* (which leaf, which block sizes) is identical for both impls,
so CPU tests exercise the same decision path the TPU build would take.

Warm-path fast lane: each pallas op builds its data mapping as an items
tuple and calls ``DispatchCache.warm_callable`` — one lock-free dict lookup
returning the pre-built kernel callable when the triple was frozen
(``DispatchCache.freeze``, fed by serving warm-up), else a locked LRU
resolve plus the family's *memoized* ``instantiate``.  Either way the
steady state performs zero ``pallas_call``/partial rebuilds and hands jax
an identity-stable callable, so jit tracing keys do not churn
(``get_default_cache`` itself is a lock-free read once installed).
"""
from __future__ import annotations

from typing import Mapping, Optional

import jax

from ..artifacts.dispatch import get_default_cache
from ..core.params import MachineDescription, TPU_V5E
from ..core.select import Candidate
from . import ref
from .flash_attention import FAMILY as FLASH_FAMILY
from .jacobi1d import FAMILY as JACOBI_FAMILY
from .matadd import FAMILY as MATADD_FAMILY
from .matmul import FAMILY as MATMUL_FAMILY
from .ssd_scan import FAMILY as SSD_FAMILY
from .transpose import FAMILY as TRANSPOSE_FAMILY

FAMILIES = {f.name: f for f in (MATMUL_FAMILY, MATADD_FAMILY, JACOBI_FAMILY,
                                TRANSPOSE_FAMILY, FLASH_FAMILY, SSD_FAMILY)}


def _resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def select(family_name: str, data: Mapping[str, int],
           machine: MachineDescription = TPU_V5E) -> Candidate:
    """Resolve the kernel variant through the process-wide DispatchCache.

    Steady-state (the serving hot path) this is one lock-free frozen-plan
    lookup when the triple was frozen at warm-up, else one LRU lookup; a
    full miss falls back to the precompiled per-machine dispatch artifact,
    and only a shape never compiled offline pays for tree enumeration."""
    return get_default_cache().best_variant(FAMILIES[family_name], machine,
                                            data)


# -- matmul -------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array, *, impl: str = "auto",
           machine: MachineDescription = TPU_V5E,
           interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.matmul(a, b)
    M, K = a.shape
    N = b.shape[1]
    fn = get_default_cache().warm_callable(
        MATMUL_FAMILY, machine, (("M", M), ("N", N), ("K", K)), interpret)
    return fn(a, b)


# -- matadd -------------------------------------------------------------------

def matadd(a: jax.Array, b: jax.Array, *, impl: str = "auto",
           machine: MachineDescription = TPU_V5E,
           interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.matadd(a, b)
    M, N = a.shape
    fn = get_default_cache().warm_callable(
        MATADD_FAMILY, machine, (("M", M), ("N", N)), interpret)
    return fn(a, b)


# -- jacobi1d -------------------------------------------------------------------

def jacobi1d(x: jax.Array, steps: int, *, impl: str = "auto",
             machine: MachineDescription = TPU_V5E,
             interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.jacobi1d(x, steps)
    (n,) = x.shape
    fn = get_default_cache().warm_callable(
        JACOBI_FAMILY, machine, (("N", n),), interpret)
    return fn(x, steps)


# -- transpose -----------------------------------------------------------------

def transpose(a: jax.Array, *, impl: str = "auto",
              machine: MachineDescription = TPU_V5E,
              interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.transpose(a)
    M, N = a.shape
    fn = get_default_cache().warm_callable(
        TRANSPOSE_FAMILY, machine, (("M", M), ("N", N)), interpret)
    return fn(a)


# -- flash attention -----------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    impl: str = "auto",
                    machine: MachineDescription = TPU_V5E,
                    interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.flash_attention(q, k, v, causal=causal, window=window)
    h, sq, d = q.shape
    fn = get_default_cache().warm_callable(
        FLASH_FAMILY, machine, (("SQ", sq), ("HD", d)), interpret)
    return fn(q, k, v, causal=causal, window=window)


# -- SSD scan --------------------------------------------------------------------

def ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
             impl: str = "auto", machine: MachineDescription = TPU_V5E,
             interpret: bool = False) -> jax.Array:
    impl = _resolve_impl(impl)
    if impl == "xla":
        return ref.ssd_scan(x, a, b, c)
    seq, heads, hd = x.shape
    state = b.shape[-1]
    fn = get_default_cache().warm_callable(
        SSD_FAMILY, machine,
        (("SQ", seq), ("HD", hd), ("STATE", state)), interpret)
    return fn(x, a, b, c)
