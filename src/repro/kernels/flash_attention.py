"""Parametric flash attention (online softmax) for the LM substrate.

This is the framework hot-spot the paper's technique drives for every
attention architecture: block sizes (bq, bk) are program parameters, VMEM is
the binding resource, and the comprehensive tree decides between the
full-grain and reduced-grain variants per machine.

Supports causal masking, GQA (kv heads broadcast outside the kernel), sliding
windows (hymba long-context), and KV-cache decode (q shorter than k, end
aligned).
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               bq: int, bk: int, nk: int, scale: float, causal: bool,
               window: int | None, q_offset: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                       # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                       # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qidx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kidx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kidx <= qidx
    if window is not None:
        mask &= kidx > qidx - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)             # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                 # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                         # (bq, 1)
    l_ref[:, :1] = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[:, :1] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _flush():
        l = l_ref[:, :1]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def pallas_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int, bk: int, causal: bool = True,
                           window: int | None = None,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: [h, sq, d]; k,v: [h, sk, d] (sq <= sk, end-aligned for decode)."""
    h, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    sq_p = -(-sq // bq_) * bq_
    sk_p = -(-sk // bk_) * bk_
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0)))
    # padded K columns must never win the softmax: mask via kidx >= sk
    nk = sk_p // bk_
    grid = (h, sq_p // bq_, nk)
    # emulate "end aligned" decode: query global index offset
    q_offset = sk - sq

    # padded keys: handled by causal mask when causal (kidx > qidx for pads
    # iff qidx < sk). For non-causal, clamp via explicit window on sk.
    eff_window = window
    out = pl.pallas_call(
        functools.partial(_fa_kernel, bq=bq_, bk=bk_, nk=nk, scale=scale,
                          causal=causal, window=eff_window,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]


class FlashAttentionFamily(CachedInstantiationMixin):
    name = "flash_attention"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"granularity_level": 0},
            program_params={
                "bq": ParamDomain("bq", (128, 256, 512), align=128),
                "bkv": ParamDomain("bkv", (128, 256, 512), align=128),
            },
        )

    def counters(self) -> Sequence[Counter]:
        return [
            resource("vmem_bytes", "V", ("reduce_q_block",),
                     "q/k/v/acc blocks + p tile"),
            resource("vreg_pressure", "G", (),
                     "softmax state rows live per step"),
            performance("occupancy", "P_occ", ("reduce_q_block",)),
        ]

    def strategies(self) -> Sequence[Strategy]:
        def reduce_q_block(plan: KernelPlan):
            if plan.flags.get("granularity_level", 0) >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce q block")
            p.program_params["bq"] = ParamDomain("bq", (128,), align=128)
            return p

        return [Strategy("reduce_q_block", reduce_q_block)]

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        bq, bkv, hd = V("bq"), V("bkv"), V("HD")
        one = Poly.const(1)
        if counter == "vmem_bytes":
            blocks = 2 * 2 * (bq * hd + 2 * bkv * hd)       # dbl-buffered bf16
            scratch = 4 * (bq * hd + 2 * bq * 128) + 4 * bq * bkv
            return blocks + scratch, one
        if counter == "vreg_pressure":
            return bq * (V("HD") + 2 * 128) / (8 * 128), one
        if counter == "occupancy":
            return V("CORES") * bq, V("SQ")
        raise KeyError(counter)

    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        import math
        bq, bkv = v["bq"], v["bkv"]
        sq = v.get("SQ", 4096)
        fill = min(1.0, bq / 128) * min(1.0, bkv / 128)
        waves = math.ceil(sq / bq) / max(1, v.get("CORES", 1))
        reuse = min(1.0, (bq * bkv) / (256 * 256))
        return fill * min(1.0, waves) * (0.5 + 0.5 * reuse)

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        return functools.partial(
            pallas_flash_attention, bq=int(assignment["bq"]),
            bk=int(assignment["bkv"]), interpret=interpret)


FAMILY = FlashAttentionFamily()
