"""Instantiation fast lane: memoized kernel builders with stable identity.

The paper's offline/online split resolves the *case discussion* before the
hot loop — but resolving a :class:`~repro.core.select.Candidate` is only half
of a warm op call.  The other half is ``FamilySpec.instantiate``, which
historically returned a **fresh** ``functools.partial`` (wrapping
``pl.pallas_call`` construction) on every invocation.  That churns two
things serving cares about:

- per-call Python allocation on the steady-state path, and
- the identity of the callable handed to jax — every fresh partial is a new
  tracing key, so downstream ``jax.jit`` caches never stabilize.

:class:`CachedInstantiationMixin` fixes both: each kernel family implements
the raw builder as ``_build(plan, assignment, interpret)`` and inherits an
``instantiate`` that memoizes on

    ``(family, leaf_index, frozen assignment, frozen plan flags, interpret)``

so repeated resolutions of the same triple return the *same object*.  The
plan flags fully determine the builder's behaviour (``_build`` consumes only
flags + assignment), so the optional ``leaf_index`` hint can only split the
cache, never alias two different kernels onto one entry.

Thread notes: reads are lock-free (GIL-atomic ``dict.get``); misses take a
per-cache lock, double-check, build once, and publish.  Eviction is
insertion-order (FIFO) at ``maxsize`` — identity is stable while an entry
lives, and the cap is far above any real family's variant count, so eviction
is a memory backstop, not an expected event.  ``hits`` is maintained without
the lock and may undercount under extreme contention; ``misses`` (the number
of builder invocations — what the zero-rebuild tests assert on) is exact.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from ..core.plan import KernelPlan

InstantiationKey = Tuple[str, Optional[int], Tuple[Tuple[str, Any], ...],
                         Tuple[Tuple[str, int], ...], bool]

#: Every cache ever constructed, so tests can reset the process state.
#: Families are module singletons — this list stays tiny and never cycles.
_ALL_CACHES: List["InstantiationCache"] = []
_REGISTRY_LOCK = threading.Lock()


def freeze_flags(flags: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    """Canonical hashable form of a plan's flag dict."""
    return tuple(sorted(flags.items()))


def freeze_assignment(assignment: Mapping[str, int]
                      ) -> Tuple[Tuple[str, int], ...]:
    """Canonical hashable form of a program-parameter assignment."""
    return tuple(sorted((k, int(v)) for k, v in assignment.items()))


def instantiation_key(family_name: str, plan: KernelPlan,
                      assignment: Mapping[str, int], interpret: bool,
                      leaf_index: Optional[int] = None) -> InstantiationKey:
    return (family_name, leaf_index, freeze_flags(plan.flags),
            freeze_assignment(assignment), bool(interpret))


class InstantiationCache:
    """Identity-stable memo of built kernel callables (one per family)."""

    def __init__(self, maxsize: int = 512):
        self.maxsize = maxsize
        self.hits = 0                      # approximate (lock-free reads)
        self.misses = 0                    # exact (builder invocations)
        self._fns: Dict[InstantiationKey, Callable] = {}
        self._lock = threading.Lock()
        with _REGISTRY_LOCK:
            _ALL_CACHES.append(self)

    def get_or_build(self, key: InstantiationKey,
                     builder: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)            # lock-free warm path
        if fn is not None:
            self.hits += 1
            return fn
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                self.misses += 1
                fn = builder()
                if len(self._fns) >= self.maxsize:
                    self._fns.pop(next(iter(self._fns)))   # FIFO backstop
                self._fns[key] = fn
        return fn

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self.hits = self.misses = 0

    def __len__(self) -> int:
        return len(self._fns)


def clear_instantiation_caches() -> None:
    """Reset every family's instantiation cache (test isolation)."""
    with _REGISTRY_LOCK:
        caches = list(_ALL_CACHES)
    for c in caches:
        c.clear()


class CachedInstantiationMixin:
    """Gives a kernel family an identity-stable ``instantiate``.

    Families implement ``_build(plan, assignment, interpret)`` — the raw
    constructor that wires ``pl.pallas_call`` — and inherit the memoized
    public entry point.  ``instantiate_fresh`` bypasses the cache (used by
    benchmarks to measure the pre-fast-lane rebuild cost)."""

    name: str

    @property
    def instantiation_cache(self) -> InstantiationCache:
        cache = self.__dict__.get("_inst_cache")
        if cache is None:                  # families are singletons; benign
            cache = self.__dict__.setdefault("_inst_cache",
                                             InstantiationCache())
        return cache

    def instantiate(self, plan: KernelPlan, assignment: Mapping[str, int],
                    interpret: bool = False, *,
                    leaf_index: Optional[int] = None) -> Callable:
        key = instantiation_key(self.name, plan, assignment, interpret,
                                leaf_index)
        return self.instantiation_cache.get_or_build(
            key, lambda: self._build(plan, assignment, interpret))

    def instantiate_fresh(self, plan: KernelPlan,
                          assignment: Mapping[str, int],
                          interpret: bool = False) -> Callable:
        """The pre-fast-lane path: rebuild the callable, no memo."""
        return self._build(plan, assignment, interpret)

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool) -> Callable:
        raise NotImplementedError
