"""Pure-jnp oracles for every Pallas kernel family.

Each function is the semantic ground truth the per-kernel allclose sweeps in
``tests/test_kernels.py`` compare against (any leaf variant of the
comprehensive tree must match these — code soundness, Def. 2 (ii)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation (paper Fig. 3/4)."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)
                      ).astype(out_dtype)


def matadd(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A + B (paper Fig. 1/2)."""
    return a + b


def jacobi1d(a: jax.Array, steps: int) -> jax.Array:
    """1D Jacobi with fixed boundary (paper Fig. 7).

    ``a`` has length n; interior points are averaged over the 3-stencil for
    ``steps`` time iterations; boundary values stay fixed.
    """
    def one(x):
        inner = (x[:-2] + x[1:-1] + x[2:]) / 3
        return x.at[1:-1].set(inner)

    for _ in range(steps):
        a = one(a)
    return a


def transpose(a: jax.Array) -> jax.Array:
    """B = A^T (paper Fig. 8)."""
    return a.T


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """Softmax attention oracle.  q,k,v: [heads, seq, head_dim]."""
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sq, sk = q.shape[-2], k.shape[-2]
    idx_q = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (KV cache decode)
    idx_k = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= idx_k <= idx_q
    if window is not None:
        mask &= idx_k > (idx_q - window)
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array
             ) -> jax.Array:
    """Mamba-2 SSD (state-space dual) sequential oracle.

    x: [seq, heads, head_dim]   input
    a: [seq, heads]             per-step log-decay (a_t in (0,1) after exp)
    b: [seq, heads, state]      input projection
    c: [seq, heads, state]      output projection
    Recurrence per head:  S_t = a_t * S_{t-1} + b_t ⊗ x_t ;  y_t = c_t · S_t
    """
    seq, heads, hd = x.shape
    state = b.shape[-1]

    def step(S, inp):
        x_t, a_t, b_t, c_t = inp
        S = a_t[:, None, None] * S + jnp.einsum("hs,hd->hsd", b_t, x_t)
        y = jnp.einsum("hs,hsd->hd", c_t, S)
        return S, y

    S0 = jnp.zeros((heads, state, hd), jnp.float32)
    _, y = jax.lax.scan(step, S0, (x.astype(jnp.float32),
                                   a.astype(jnp.float32),
                                   b.astype(jnp.float32),
                                   c.astype(jnp.float32)))
    return y.astype(x.dtype)
