"""Parametric blocked matmul — the paper's flagship kernel (Fig. 3/4, Table 1).

GPU→TPU mapping (DESIGN.md §2): the paper's thread-block format ``B0 × ub1``
with grain ``s`` (coefficients per thread) becomes a Pallas ``BlockSpec`` tile
``bm × (s·bn)`` with grain ``s`` (bn-wide MXU sub-tiles per grid step); the
``__shared__`` staging of A/B blocks becomes VMEM staging with an explicit f32
accumulator scratch (``cached``) versus output-block accumulation
(``uncached``).

Program parameters:  bm, bn, bk, s   (all symbolic during tree construction)
Data parameters:     M, N, K
Machine parameters:  V (VMEM bytes), G (vreg budget), CORES, MXU
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin

DIN = 2      # bf16 input bytes
DACC = 4     # f32 accumulator bytes


# =============================================================================
# Pallas kernels (one per comprehensive-tree leaf shape)
# =============================================================================

def _mm_kernel_cached(a_ref, b_ref, o_ref, acc_ref, *, s: int, bn: int,
                      nk: int):
    """VMEM-cached variant: f32 scratch accumulator, grain loop over s."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                      # (bm, bk)
    for t in range(s):                                      # paper's grain s
        acc_ref[:, t * bn:(t + 1) * bn] += jnp.dot(
            a, b_ref[:, t * bn:(t + 1) * bn].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_uncached(a_ref, b_ref, o_ref, *, s: int, bn: int, nk: int):
    """Uncached variant: accumulate straight into the (f32) output block."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    for t in range(s):
        o_ref[:, t * bn:(t + 1) * bn] += jnp.dot(
            a, b_ref[:, t * bn:(t + 1) * bn].astype(jnp.float32),
            preferred_element_type=jnp.float32)


def pallas_matmul(a: jax.Array, b: jax.Array, *, bm: int, bn: int, bk: int,
                  s: int, cached: bool = True, interpret: bool = False
                  ) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with parametric blocking (pads to tiles)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bn_tot = bn * s
    Mp = -(-M // bm) * bm
    Np = -(-N // bn_tot) * bn_tot
    Kp = -(-K // bk) * bk
    a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    grid = (Mp // bm, Np // bn_tot, Kp // bk)

    common = dict(
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn_tot), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn_tot), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )
    if cached:
        out = pl.pallas_call(
            functools.partial(_mm_kernel_cached, s=s, bn=bn, nk=grid[2]),
            scratch_shapes=[pltpu.VMEM((bm, bn_tot), jnp.float32)],
            **common,
        )(a, b)
    else:
        out = pl.pallas_call(
            functools.partial(_mm_kernel_uncached, s=s, bn=bn, nk=grid[2]),
            **common,
        )(a, b)
    return out[:M, :N]


# =============================================================================
# FamilySpec — symbolic counters + strategies for the comprehensive tree
# =============================================================================

_S_DOMAIN_BY_LEVEL = {0: (1, 2, 4, 8), 1: (1, 2)}


class MatmulFamily(CachedInstantiationMixin):
    name = "matmul"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"vmem_cache": True, "granularity_level": 0,
                   "pressure_level": 0, "cse_level": 0},
            program_params={
                "bm": ParamDomain("bm", (8, 16, 32, 64, 128, 256), align=8),
                "bn": ParamDomain("bn", (128, 256, 512), align=128),
                "bk": ParamDomain("bk", (128, 256, 512), align=128),
                "s": ParamDomain("s", _S_DOMAIN_BY_LEVEL[0]),
            },
        )

    # -- counters (order: resources r_i first, then performance p_i) ---------
    def counters(self) -> Sequence[Counter]:
        return [
            resource("vmem_bytes", "V",
                     ("reduce_granularity", "uncache"),
                     "VMEM working set per grid step (paper: Z_B)"),
            resource("vreg_pressure", "G",
                     ("pressure_1", "pressure_2", "pressure_3",
                      "cse_1", "cse_2"),
                     "live lane-values per grid step (paper: registers R)"),
            performance("occupancy", "P_occ", ("reduce_granularity",),
                        "cores per grid step (paper: SM occupancy)"),
            performance("mxu_util", "P_mxu", (),
                        "MXU systolic tile fill ratio"),
        ]

    # -- strategies O_1..O_w (paper §5: 4 kinds; 3 pressure + 2 cse levels) --
    def strategies(self) -> Sequence[Strategy]:
        def reduce_granularity(plan: KernelPlan):
            lvl = plan.flags.get("granularity_level", 0)
            if lvl >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce granularity")
            p.program_params["s"] = ParamDomain("s", _S_DOMAIN_BY_LEVEL[1])
            return p

        def uncache(plan: KernelPlan):
            if not plan.flags.get("vmem_cache", True):
                return None
            return plan.with_flag("vmem_cache", False, "drop VMEM staging")

        def pressure(level):
            def apply(plan: KernelPlan):
                if plan.flags.get("pressure_level", 0) >= level:
                    return None
                return plan.with_flag("pressure_level", level,
                                      f"split accumulator L{level}")
            return apply

        def cse(level):
            def apply(plan: KernelPlan):
                if plan.flags.get("cse_level", 0) >= level:
                    return None
                return plan.with_flag("cse_level", level, f"CSE L{level}")
            return apply

        return [
            Strategy("reduce_granularity", reduce_granularity),
            Strategy("uncache", uncache),
            Strategy("pressure_1", pressure(1)),
            Strategy("pressure_2", pressure(2)),
            Strategy("pressure_3", pressure(3)),
            Strategy("cse_1", cse(1)),
            Strategy("cse_2", cse(2)),
        ]

    # -- symbolic counter evaluation (paper §3.3: f_i, g_i) -------------------
    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        bm, bn, bk, s = V("bm"), V("bn"), V("bk"), V("s")
        one = Poly.const(1)
        if counter == "vmem_bytes":
            streamed = 2 * DIN * (bm * bk + bk * bn * s)   # double-buffered
            outblk = DACC * bm * bn * s
            if plan.flags.get("vmem_cache", True):
                return streamed + outblk + DACC * bm * bn * s, one
            return streamed + outblk, one
        if counter == "vreg_pressure":
            p = plan.flags.get("pressure_level", 0)
            c = plan.flags.get("cse_level", 0)
            acc_tiles = bm * bn * s / (8 * 128 * (2 ** p))
            index_regs = Poly.const(12 - 3 * c)
            return acc_tiles + index_regs, one
        if counter == "occupancy":
            return V("CORES") * bm * bn * s, V("M") * V("N")
        if counter == "mxu_util":
            return bm * bn, V("MXU") * V("MXU")
        raise KeyError(counter)

    # -- offline ranking model (napkin math over the v5e datapath) -----------
    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        import math
        bm, bn, bk, s = v["bm"], v["bn"], v["bk"], v["s"]
        M = v.get("M", 4096); N = v.get("N", 4096)
        mxu = v.get("MXU", 128)
        cores = max(1, v.get("CORES", 1))
        bns = bn * s
        fill = min(1.0, bm / mxu) * min(1.0, bn / mxu)   # MXU tile fill
        ai = (bm * bns) / (bm + bns)                      # tile FLOP/byte reuse
        ai_norm = min(1.0, ai / 256.0)
        waves = (math.ceil(M / bm) * math.ceil(N / bns)) / cores
        wave_eff = min(1.0, waves)                        # enough parallelism
        kamort = min(1.0, bk / 512)                       # fewer k revisits
        return fill * ai_norm * wave_eff * (0.5 + 0.5 * kamort)

    def score_batch(self, plan: KernelPlan, v: Mapping[str, object]):
        """Vectorized twin of ``score`` over NumPy columns (same ops in the
        same order, so per-row results match the scalar model bit-for-bit)."""
        import numpy as np
        bm, bn = np.asarray(v["bm"]), np.asarray(v["bn"])
        bk, s = np.asarray(v["bk"]), np.asarray(v["s"])
        M = v.get("M", 4096); N = v.get("N", 4096)
        mxu = v.get("MXU", 128)
        cores = max(1, v.get("CORES", 1))
        bns = bn * s
        fill = np.minimum(1.0, bm / mxu) * np.minimum(1.0, bn / mxu)
        ai = (bm * bns) / (bm + bns)
        ai_norm = np.minimum(1.0, ai / 256.0)
        waves = (np.ceil(M / bm) * np.ceil(N / bns)) / cores
        wave_eff = np.minimum(1.0, waves)
        kamort = np.minimum(1.0, bk / 512)
        return fill * ai_norm * wave_eff * (0.5 + 0.5 * kamort)

    # -- instantiation (memoized by CachedInstantiationMixin.instantiate) ----
    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        bm, bn = int(assignment["bm"]), int(assignment["bn"])
        bk, s = int(assignment["bk"]), int(assignment["s"])
        cached = bool(plan.flags.get("vmem_cache", True))
        return functools.partial(pallas_matmul, bm=bm, bn=bn, bk=bk, s=s,
                                 cached=cached, interpret=interpret)


FAMILY = MatmulFamily()
