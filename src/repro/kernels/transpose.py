"""Parametric matrix transposition (paper Fig. 8, Table 3).

The comprehensive tree reproduces the paper's three-case discussion:

  case 1:  2·s·B0·B1 <= Z_B            cache + full grain      (VMEM staged)
  case 2:  2·B0·B1 <= Z_B < 2·s·B0·B1  cache + reduced grain
  case 3:  Z_B < 2·B0·B1               no cache                (direct copy)

with Z_B -> V (VMEM bytes).  The cached variant stages the input tile in a
VMEM scratch and writes the transposed tile out (on GPU this is the classic
shared-memory-bank transpose; on TPU it keeps the relayout inside VMEM where
the copy-transpose unit operates on (8,128) tiles).
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.counters import Counter, performance, resource
from ..core.plan import KernelPlan, ParamDomain
from ..core.polynomial import Poly, V
from ..core.strategies import Strategy
from .instantiate_cache import CachedInstantiationMixin

DT = 4


def _tr_kernel_cached(a_ref, o_ref, scratch_ref, *, s: int, bn: int):
    for t in range(s):                          # grain loop (paper's k loop)
        sl = slice(t * bn, (t + 1) * bn)
        scratch_ref[sl, :] = a_ref[:, sl].T
    o_ref[...] = scratch_ref[...]


def _tr_kernel_uncached(a_ref, o_ref, *, s: int, bn: int):
    for t in range(s):
        sl = slice(t * bn, (t + 1) * bn)
        o_ref[sl, :] = a_ref[:, sl].T


def pallas_transpose(a: jax.Array, *, bm: int, bn: int, s: int,
                     cached: bool = True, interpret: bool = False
                     ) -> jax.Array:
    M, N = a.shape
    bn_tot = bn * s
    Mp, Np = -(-M // bm) * bm, -(-N // bn_tot) * bn_tot
    a = jnp.pad(a, ((0, Mp - M), (0, Np - N)))
    common = dict(
        grid=(Mp // bm, Np // bn_tot),
        in_specs=[pl.BlockSpec((bm, bn_tot), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn_tot, bm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), a.dtype),
        interpret=interpret,
    )
    if cached:
        out = pl.pallas_call(
            functools.partial(_tr_kernel_cached, s=s, bn=bn),
            scratch_shapes=[pltpu.VMEM((bn_tot, bm), a.dtype)],
            **common)(a)
    else:
        out = pl.pallas_call(
            functools.partial(_tr_kernel_uncached, s=s, bn=bn),
            **common)(a)
    return out[:N, :M]


class TransposeFamily(CachedInstantiationMixin):
    name = "transpose"

    def initial_plan(self) -> KernelPlan:
        return KernelPlan(
            family=self.name,
            flags={"vmem_cache": True, "granularity_level": 0, "cse_level": 0},
            program_params={
                "bm": ParamDomain("bm", (8, 16, 32, 64, 128, 256), align=8),
                "bn": ParamDomain("bn", (128, 256), align=128),
                "s": ParamDomain("s", (1, 2, 4, 8)),
            },
        )

    def counters(self) -> Sequence[Counter]:
        return [
            resource("vmem_bytes", "V", ("reduce_granularity", "uncache"),
                     "paper: 2*s*B0*B1 words of shared memory (Z_B)"),
            resource("vreg_pressure", "G", ("cse_1", "cse_2"),
                     "paper: 6 at source, 5 after CSE"),
            performance("occupancy", "P_occ", ("reduce_granularity",)),
        ]

    def strategies(self) -> Sequence[Strategy]:
        def reduce_granularity(plan: KernelPlan):
            if plan.flags.get("granularity_level", 0) >= 1:
                return None
            p = plan.with_flag("granularity_level", 1, "reduce granularity")
            p.program_params["s"] = ParamDomain("s", (1,))
            return p

        def uncache(plan: KernelPlan):
            if not plan.flags.get("vmem_cache", True):
                return None
            return plan.with_flag("vmem_cache", False, "drop VMEM staging")

        def cse(level):
            def apply(plan: KernelPlan):
                if plan.flags.get("cse_level", 0) >= level:
                    return None
                return plan.with_flag("cse_level", level, f"CSE L{level}")
            return apply

        return [Strategy("reduce_granularity", reduce_granularity),
                Strategy("uncache", uncache),
                Strategy("cse_1", cse(1)), Strategy("cse_2", cse(2))]

    def counter_value(self, plan: KernelPlan, counter: str
                      ) -> Tuple[Poly, Poly]:
        bm, bn, s = V("bm"), V("bn"), V("s")
        one = Poly.const(1)
        if counter == "vmem_bytes":
            io = 2 * DT * bm * bn * s                   # in + out blocks
            if plan.flags.get("vmem_cache", True):
                return io + DT * bm * bn * s, one       # + scratch (paper 2sB0B1)
            return io, one
        if counter == "vreg_pressure":
            c = plan.flags.get("cse_level", 0)
            return Poly.const(6 - min(c, 1)), one       # paper: 6 -> 5
        if counter == "occupancy":
            return V("CORES") * bm * bn * s, V("M") * V("N")
        raise KeyError(counter)

    def score(self, plan: KernelPlan, v: Mapping[str, int]) -> float:
        import math
        bm, bn, s = v["bm"], v["bn"], v["s"]
        M = v.get("M", 4096); N = v.get("N", 4096)
        # transposes love square-ish tiles that fill (8,128) vregs both ways
        fill = min(1.0, bm / 128) * min(1.0, bn / 128)
        balance = min(bm, bn * s) / max(bm, bn * s)
        waves = (math.ceil(M / bm) * math.ceil(N / (bn * s))) \
            / max(1, v.get("CORES", 1))
        return fill * balance * min(1.0, waves)

    def _build(self, plan: KernelPlan, assignment: Mapping[str, int],
               interpret: bool = False) -> Callable:
        return functools.partial(
            pallas_transpose, bm=int(assignment["bm"]),
            bn=int(assignment["bn"]), s=int(assignment["s"]),
            cached=bool(plan.flags.get("vmem_cache", True)),
            interpret=interpret)


FAMILY = TransposeFamily()
