"""Traced warm-sets + portable serve-plan artifacts.

Closes the deployment side of the paper's offline/online split: instead of
every serving process re-deriving (or hand-listing) the kernel-variant warm
set, the exact ``(family, machine, data)`` set a :class:`ModelConfig` will
dispatch is *traced* from the model structure once, resolved offline
against the compiled/tuned dispatch tables, and shipped as a versioned
**serve-plan artifact** next to those tables.  At engine start the plan is
fed straight to ``DispatchCache.freeze_resolved`` — zero online tree
enumeration, ``stats.cold_builds == 0``.

- :mod:`repro.plans.trace`  — abstract prefill/decode/train step drivers +
  the ``DispatchCache.record`` replay (the warm-set derivation)
- :mod:`repro.plans.serde`  — ``PLAN_FORMAT_VERSION``-stamped,
  byte-deterministic payloads (version-mismatch-reads-as-miss)
- :mod:`repro.plans.store`  — ``<root>/plans/<config>/serve-v<V>-<machine>
  .json`` next to the dispatch artifacts
- :mod:`repro.plans.loader` — offline ``build_serve_plan``; online
  ``warm_from_plan`` (load, validate, freeze)

Workflow: ``scripts/compile_artifacts.py`` → ``scripts/tune_artifacts.py``
→ ``scripts/plan_artifacts.py`` → ship the artifact dir (docs/tuning.md).
"""
from .serde import PLAN_FORMAT_VERSION, PlanEntry, ServePlan
from .store import PlanStore, resolve_env_store
from .trace import TracedOp, op_label, record_warm_set, trace_warm_set
from .loader import (StalePlanError, StalePlanWarning, apply_serve_plan,
                     build_serve_plan, load_serve_plan, plan_staleness,
                     table_digest, warm_from_plan)

__all__ = [
    "PLAN_FORMAT_VERSION", "PlanEntry", "ServePlan",
    "PlanStore", "resolve_env_store",
    "TracedOp", "op_label", "record_warm_set", "trace_warm_set",
    "StalePlanError", "StalePlanWarning",
    "apply_serve_plan", "build_serve_plan", "load_serve_plan",
    "plan_staleness", "table_digest", "warm_from_plan",
]
