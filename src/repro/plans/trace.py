"""Trace the exact kernel warm set a :class:`ModelConfig` will dispatch.

PR 4's ``warm_kernel_dispatch`` warmed a *hand-listed* triple set (flash
attention plus three matmuls) — silently missing ``ssd_scan`` for Mamba/
hybrid configs, the MoE router/expert projections, the whisper encoder
shapes, and every SSM projection.  This module derives the warm set from the
config itself: abstract step drivers walk the model structure exactly as
:mod:`repro.models.transformer` assembles it (prefill/decode serve steps,
optionally the train step) and emit one dispatch request per kernel-family
op the step would perform, with the data parameters computed from the config
dims.  Nothing is executed — the drivers are an abstract interpretation of
the step over shapes.

Two consumption modes:

- :func:`trace_warm_set` — pure derivation: the ordered, deduplicated
  :class:`TracedOp` list (no cache touched, no resolution paid).
- :func:`record_warm_set` — replay the same requests through the live
  dispatch layer (``DispatchCache.best_variant`` under
  :meth:`DispatchCache.record`), returning what the cache actually saw.
  This is the fidelity check — traced and recorded sets must agree — and it
  warms the LRU as a side effect, which is what serving warm-up wants.

Width conventions (why some real ops are deliberately untraced): the
blocked kernel families only engage at tile scale — a shape with
``M·N < SUBLANE·LANE`` (1024 on v5e) has no feasible leaf, so decode-pool
GEMV work (``M = batch``) is *not* traced; projections are traced at the
token-parallel prefill width (``M = max_len``), matching what the paper's
blocked kernels actually serve.  Attention/SSD cores are traced at both the
prefill window and ``2·max_len`` (the decode-context guard band the legacy
hand list established).  A traced triple may still be infeasible for an
extreme config (e.g. a tiny MoE router at short ``max_len``); resolution-
time consumers drop those (``build_serve_plan``/``warm_kernel_dispatch``),
trace itself stays an honest statement of what the model would ask for.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

from ..core.params import MachineDescription, TPU_V5E
from ..models.config import ModelConfig
from ..models.moe import MOE_GROUP_SIZE, capacity


def op_label(family: str, data: Dict[str, int]) -> str:
    """Canonical label for a traced (family, data) pair, e.g.
    ``matmul@K4096xM512xN14336`` — unique per triple, stable across runs."""
    return family + "@" + "x".join(f"{k}{int(v)}"
                                   for k, v in sorted(data.items()))


@dataclass(frozen=True)
class TracedOp:
    """One deduplicated warm-set member: a (family, data) pair plus every
    abstract call site that requested it (e.g. both MLP up- and gate-
    projections share one matmul triple)."""

    label: str
    family: str
    data: Tuple[Tuple[str, int], ...]        # sorted items, hashable
    sites: Tuple[str, ...]

    def data_dict(self) -> Dict[str, int]:
        return dict(self.data)


# ---------------------------------------------------------------------------
# Abstract step drivers
# ---------------------------------------------------------------------------

def _iter_requests(cfg: ModelConfig, *, max_len: int, page_size: int,
                   include_train: bool, train_seq: int, train_batch: int
                   ) -> Iterator[Tuple[str, str, Dict[str, int]]]:
    """Yield ``(site, family, data)`` per abstract kernel op, serve steps
    first, then (optionally) the train step.  Mirrors the block families of
    ``models.transformer.block_apply``."""
    yield from _step_requests(cfg, tokens=max_len, prefix="serve",
                              decode_guard=True, page_size=page_size)
    if include_train:
        yield from _step_requests(cfg, tokens=train_batch * train_seq,
                                  seq=train_seq, prefix="train",
                                  decode_guard=False)


def _step_requests(cfg: ModelConfig, *, tokens: int, prefix: str,
                   decode_guard: bool, seq: Optional[int] = None,
                   page_size: int = 0
                   ) -> Iterator[Tuple[str, str, Dict[str, int]]]:
    """One step's ops.  ``tokens`` is the token-parallel matmul width M;
    ``seq`` the attention/scan sequence length (defaults to ``tokens``).
    ``decode_guard`` additionally traces the cores at ``2·seq`` — the
    growing-context shapes the decode loop reaches after prefill.
    ``page_size > 0`` is the paged-KV serve path: the attention gather
    extent is the block grid (``ceil(seq/page_size)·page_size``), so the
    attention-core bucket keys carry the block size (a ``max_len`` already
    on the grid traces identically to the dense path).  Prefix sharing and
    copy-on-write add **no** shapes to this set: a prefix-mapped sequence
    still dispatches the same block-grid attention extents and quantized
    chunk widths (only *which* chunks run changes), and the CoW block copy
    is a scalar-indexed cache update, not a traced kernel op — so a frozen
    serve plan stays exhaustive with ``prefix_sharing`` on
    (``tests/test_plans.py`` asserts cold_builds == 0)."""
    d, hd = cfg.d_model, cfg.hd
    seq = seq if seq is not None else tokens
    has_attn = cfg.block in ("attn_mlp", "attn_moe", "hybrid")
    has_ssm = cfg.block in ("ssm", "hybrid")
    has_mlp = cfg.block in ("attn_mlp", "hybrid") or (
        cfg.block == "ssm" and cfg.d_ff > 0)
    core_seqs = (seq, 2 * seq) if decode_guard else (seq,)
    aseq = -(-seq // page_size) * page_size if page_size else seq
    attn_seqs = (aseq, 2 * aseq) if decode_guard else (aseq,)

    if has_attn:
        for sq in attn_seqs:
            yield (f"{prefix}.attn.core@{sq}", "flash_attention",
                   {"SQ": sq, "HD": hd})
        yield (f"{prefix}.attn.q_proj", "matmul",
               {"M": tokens, "N": cfg.heads * hd, "K": d})
        yield (f"{prefix}.attn.kv_proj", "matmul",
               {"M": tokens, "N": cfg.kv_heads * hd, "K": d})
        yield (f"{prefix}.attn.out_proj", "matmul",
               {"M": tokens, "N": d, "K": cfg.heads * hd})
    if has_ssm and cfg.ssm is not None:
        s = cfg.ssm
        di = s.heads * s.head_dim
        for sq in core_seqs:
            yield (f"{prefix}.ssm.core@{sq}", "ssd_scan",
                   {"SQ": sq, "HD": s.head_dim, "STATE": s.state})
        yield (f"{prefix}.ssm.x_proj", "matmul",
               {"M": tokens, "N": di, "K": d})
        yield (f"{prefix}.ssm.bc_proj", "matmul",
               {"M": tokens, "N": s.state, "K": d})
        yield (f"{prefix}.ssm.out_proj", "matmul",
               {"M": tokens, "N": d, "K": di})
    if has_mlp:
        f = cfg.d_ff or 4 * d
        yield (f"{prefix}.mlp.up_proj", "matmul",
               {"M": tokens, "N": f, "K": d})       # wi and wg share it
        yield (f"{prefix}.mlp.down_proj", "matmul",
               {"M": tokens, "N": d, "K": f})
    if cfg.block == "attn_moe" and cfg.moe is not None:
        m = cfg.moe
        yield (f"{prefix}.moe.router", "matmul",
               {"M": tokens, "N": m.num_experts, "K": d})
        # per-expert token count: GShard capacity per group x group count
        gsz = min(MOE_GROUP_SIZE, tokens)
        groups = -(-tokens // gsz)
        cap = groups * capacity(gsz, m.num_experts, m.top_k,
                                m.capacity_factor)
        yield (f"{prefix}.moe.expert_up", "matmul",
               {"M": cap, "N": m.d_ff_expert, "K": d})
        yield (f"{prefix}.moe.expert_down", "matmul",
               {"M": cap, "N": d, "K": m.d_ff_expert})
    if cfg.encoder is not None:
        enc = cfg.encoder
        # encoder self-attention and decoder cross-attention both attend
        # over the fixed frame axis; decode-side growth tracked above
        yield (f"{prefix}.encoder.attn.core", "flash_attention",
               {"SQ": enc.seq_len, "HD": hd})
        # encoder blocks are full attention blocks (transformer.init_layer
        # with cross=False), so their projections run at the frame width;
        # the decoder's cross-attention K/V projections over the encoder
        # output share the kv_proj triple, and its q projection runs at
        # decoder width (deduped against the self-attention q_proj above)
        yield (f"{prefix}.encoder.attn.q_proj", "matmul",
               {"M": enc.seq_len, "N": cfg.heads * hd, "K": d})
        yield (f"{prefix}.encoder.attn.kv_proj", "matmul",
               {"M": enc.seq_len, "N": cfg.kv_heads * hd, "K": d})
        yield (f"{prefix}.encoder.attn.out_proj", "matmul",
               {"M": enc.seq_len, "N": d, "K": cfg.heads * hd})
        yield (f"{prefix}.encoder.mlp.up_proj", "matmul",
               {"M": enc.seq_len, "N": cfg.d_ff or 4 * d, "K": d})
        yield (f"{prefix}.encoder.mlp.down_proj", "matmul",
               {"M": enc.seq_len, "N": d, "K": cfg.d_ff or 4 * d})
    yield (f"{prefix}.lm_head", "matmul",
           {"M": tokens, "N": cfg.vocab, "K": d})


def trace_warm_set(cfg: ModelConfig, *, max_len: int = 512,
                   page_size: int = 0,
                   include_train: bool = False, train_seq: int = 4096,
                   train_batch: int = 8) -> List[TracedOp]:
    """The config's warm set: ordered, deduplicated by (family, data).

    Pure derivation — no dispatch cache is touched and nothing resolves, so
    this is cheap enough to call on every engine start.  Deterministic: the
    same (config, max_len, page_size, train flags) always yields the same
    list in the same order (serve-plan artifacts are byte-stable because of
    it).  ``page_size > 0`` traces the paged serve path (see
    :func:`_step_requests`); 0 is the dense layout."""
    out: List[TracedOp] = []
    index: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], int] = {}
    for site, family, data in _iter_requests(
            cfg, max_len=max_len, page_size=page_size,
            include_train=include_train,
            train_seq=train_seq, train_batch=train_batch):
        items = tuple(sorted((k, int(v)) for k, v in data.items()))
        key = (family, items)
        at = index.get(key)
        if at is None:
            index[key] = len(out)
            out.append(TracedOp(label=op_label(family, data), family=family,
                                data=items, sites=(site,)))
        else:
            prev = out[at]
            out[at] = TracedOp(label=prev.label, family=prev.family,
                               data=prev.data, sites=prev.sites + (site,))
    return out


def record_warm_set(cfg: ModelConfig, *,
                    machine: MachineDescription = TPU_V5E,
                    cache=None, max_len: int = 512, page_size: int = 0,
                    include_train: bool = False, train_seq: int = 4096,
                    train_batch: int = 8) -> List[TracedOp]:
    """Drive the traced requests through the live dispatch layer and return
    what its recording mode captured.

    Every request goes through ``DispatchCache.best_variant`` under
    :meth:`DispatchCache.record` — the same entry point serving resolution
    uses — so the returned set is literally the recorded dispatch-request
    log (first-request order), re-labelled through :func:`op_label`.
    Infeasible triples (no feasible leaf at that shape) are recorded but
    dropped from the result, mirroring what warm-up can actually pin.
    Side effect: each feasible triple is resolved, warming the cache LRU."""
    from ..artifacts.dispatch import get_default_cache
    from ..kernels.ops import FAMILIES
    cache = cache if cache is not None else get_default_cache()
    traced = trace_warm_set(cfg, max_len=max_len, page_size=page_size,
                            include_train=include_train,
                            train_seq=train_seq, train_batch=train_batch)
    feasible: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], bool] = {}
    with cache.record() as rec:
        for op in traced:
            try:
                cache.best_variant(FAMILIES[op.family], machine,
                                   op.data_dict())
            except ValueError:
                feasible[(op.family, op.data)] = False
            else:
                feasible[(op.family, op.data)] = True
    sites = {(op.family, op.data): op.sites for op in traced}
    out = []
    for fname, _, data in rec.triples():
        items = tuple(sorted(data.items()))
        if not feasible.get((fname, items), False):
            continue
        out.append(TracedOp(label=op_label(fname, data), family=fname,
                            data=items, sites=sites.get((fname, items), ())))
    return out
