"""Build, load, and apply serve-plan artifacts.

Offline (``scripts/plan_artifacts.py``):

    ``build_serve_plan`` — trace the config's warm set, resolve every triple
    through the dispatch tiers (ideally against compiled/tuned tables), and
    package the resolutions as a :class:`ServePlan`.

Online (``ServeEngine`` / ``repro.launch.serve`` at startup):

    ``warm_from_plan`` — load the artifact for (config, machine), validate
    it against the *current* machine bindings and requested trace params,
    and feed it straight to ``DispatchCache.freeze_resolved``: the fast
    lane is pinned without touching a single tier, so
    ``stats.cold_builds == 0`` on a plan-backed start.  Any mismatch —
    missing file, format version, different machine bindings, different
    ``max_len``, unknown family, uninstantiable candidate — returns ``None``
    and the caller falls back to online warm-up (cache-miss-never-error,
    the PR 1 artifact policy).

Staleness (PLAN_FORMAT_VERSION 3):

    A plan records, per resolved family, the digest of the dispatch table
    its picks were resolved against (``table_digests``).  Re-tuning
    (``scripts/tune_artifacts.py``) rewrites those tables in place, so a
    shipped plan can silently pin a ranking the fleet no longer believes.
    ``plan_staleness`` compares recorded digests against the tables the
    serving host actually has; ``warm_from_plan`` treats a mismatch as a
    *loud* cache miss — a :class:`StalePlanWarning` and online warm-up by
    default, a :class:`StalePlanError` under ``strict=True`` (the engine's
    ``--strict-plans``).  The distinction from the silent misses above is
    deliberate: a stale plan is an operational bug (someone forgot to
    re-plan after re-tuning), not a routine artifact rollover.
"""
from __future__ import annotations

import os
import warnings
from typing import Any, Dict, List, Optional, Tuple

from ..artifacts import serde as artifact_serde
from ..artifacts.dispatch import DispatchCache, get_default_cache
from ..artifacts.store import _DEFAULT_ROOT, _ENV_ROOT, ArtifactStore
from ..core.params import MachineDescription, TPU_V5E
from ..models.config import ModelConfig
from .serde import PlanEntry, ServePlan
from .store import PlanStore, resolve_env_store
from .trace import TracedOp, trace_warm_set


class StalePlanWarning(UserWarning):
    """A serve plan's recorded dispatch-table digests no longer match the
    tables on this host (someone re-tuned/recompiled under the plan)."""


class StalePlanError(RuntimeError):
    """Strict-mode refusal to start from a stale serve plan."""


def table_digest(store: Optional[ArtifactStore], family_name: str,
                 machine_name: str) -> str:
    """Canonical digest of the dispatch table for (family, machine) in
    ``store`` — ``""`` when no store / no (readable) table exists.  The
    digest is over the canonical payload bytes, so any re-tune or
    recompile that changes the ranking changes the digest."""
    if store is None:
        return ""
    payload = store.load_dispatch(family_name, machine_name)
    return artifact_serde.digest(payload) if payload is not None else ""


def _resolve_dispatch_store() -> Optional[ArtifactStore]:
    """Environment-resolved dispatch-artifact store (mirrors
    ``artifacts.dispatch._resolve_env_store``)."""
    root = os.environ.get(_ENV_ROOT, _DEFAULT_ROOT)
    return ArtifactStore(root) if os.path.isdir(root) else None


def plan_staleness(plan: ServePlan, *,
                   machine: MachineDescription = TPU_V5E,
                   store: Optional[ArtifactStore] = None
                   ) -> Dict[str, Tuple[str, str]]:
    """Families whose dispatch table changed since the plan was built.

    Returns ``{family: (recorded_digest, current_digest)}`` for every
    mismatch ("" = no table on that side).  Empty dict = the plan is
    fresh.  ``store`` defaults to the environment-resolved artifact root —
    the tables the serving host's dispatch tiers would actually consult."""
    if store is None:
        store = _resolve_dispatch_store()
    out: Dict[str, Tuple[str, str]] = {}
    for family, recorded in plan.table_digests:
        current = table_digest(store, family, machine.name)
        if current != recorded:
            out[family] = (recorded, current)
    return out


# ---------------------------------------------------------------------------
# Offline: build
# ---------------------------------------------------------------------------

def build_serve_plan(cfg: ModelConfig, *,
                     machine: MachineDescription = TPU_V5E,
                     max_len: int = 512, page_size: int = 0,
                     include_train: bool = False,
                     train_seq: int = 4096, train_batch: int = 8,
                     cache: Optional[DispatchCache] = None
                     ) -> Tuple[ServePlan, List[TracedOp]]:
    """Trace + resolve one config's warm set into a shippable plan.

    Resolution goes through the given cache's normal tiers, so building
    against a store holding compiled/tuned dispatch tables bakes their
    (measured) ranking into the plan — the ``rank_source`` per entry records
    exactly that.  The digest of each family's dispatch table (or ``""``
    when none existed) is recorded in ``table_digests`` so serving hosts
    can detect when a later re-tune invalidated the picks
    (:func:`plan_staleness`).  Triples with no feasible leaf at their shape
    are dropped from the plan and returned separately for reporting."""
    from ..kernels.ops import FAMILIES
    cache = cache if cache is not None else get_default_cache()
    traced = trace_warm_set(cfg, max_len=max_len, page_size=page_size,
                            include_train=include_train,
                            train_seq=train_seq, train_batch=train_batch)
    entries: List[PlanEntry] = []
    dropped: List[TracedOp] = []
    for op in traced:
        try:
            cand, source = cache.best_variant_with_source(
                FAMILIES[op.family], machine, op.data_dict())
        except ValueError:
            dropped.append(op)               # infeasible at this shape
            continue
        entries.append(PlanEntry(label=op.label, family=op.family,
                                 data=op.data, sites=op.sites,
                                 candidate=cand, rank_source=source))
    # the staleness record: one digest per resolved family, taken from the
    # same store the resolutions above consulted (possibly attached lazily
    # by the cache's store resolver during those resolutions)
    digests = tuple(
        (f, table_digest(cache.store, f, machine.name))
        for f in sorted({e.family for e in entries}))
    plan = ServePlan(config=cfg.name, machine=machine.name,
                     machine_bindings=dict(machine.bindings()),
                     max_len=max_len, page_size=page_size,
                     include_train=include_train,
                     entries=tuple(entries),
                     table_digests=digests)
    return plan, dropped


# ---------------------------------------------------------------------------
# Online: load + apply
# ---------------------------------------------------------------------------

def load_serve_plan(cfg: ModelConfig, *,
                    machine: MachineDescription = TPU_V5E,
                    store: Optional[PlanStore] = None,
                    max_len: Optional[int] = None,
                    page_size: Optional[int] = None
                    ) -> Optional[ServePlan]:
    """Load + validate the plan for (config, machine); ``None`` on any miss.

    Validation beyond the store's own format check: the plan must name this
    config, carry the current machine *bindings* (a renamed or re-specced
    host reads as a miss, like stale dispatch tables), and — when given —
    have been traced for the same serve window (``max_len``) and paged KV
    block size (``page_size``; 0 is the dense layout)."""
    store = store if store is not None else resolve_env_store()
    if store is None:
        return None
    plan = store.load_plan(cfg.name, machine.name)
    if plan is None:
        return None
    if plan.config != cfg.name:
        return None
    if plan.machine_bindings != machine.bindings():
        return None
    if max_len is not None and plan.max_len != int(max_len):
        return None
    if page_size is not None and plan.page_size != int(page_size):
        return None
    return plan


def apply_serve_plan(plan: ServePlan, *,
                     machine: MachineDescription = TPU_V5E,
                     cache: Optional[DispatchCache] = None
                     ) -> Optional[Dict[str, Any]]:
    """Pin a loaded plan into the cache's frozen fast lane.

    Feeds every entry to ``DispatchCache.freeze_resolved`` — no tier is
    consulted, no tree enumerated.  Returns the same
    ``{label: {"candidate", "rank_source"}}`` report online warm-up
    produces, or ``None`` when the plan references an unknown kernel family
    or a candidate that fails to instantiate (mangled assignment) — nothing
    is published in that case, so a bad artifact degrades to online warm-up
    with the cache untouched."""
    from ..kernels.ops import FAMILIES
    from ..runtime import faults
    cache = cache if cache is not None else get_default_cache()
    resolved = []
    for e in plan.entries:
        family = FAMILIES.get(e.family)
        if family is None:
            return None
        resolved.append((family, machine, e.data_dict(), e.candidate,
                         e.rank_source))
    try:
        # chaos site: an injected apply failure degrades to online warm-up
        # exactly like an uninstantiable candidate would
        faults.maybe_fault("plan.apply")
        cache.freeze_resolved(resolved)
    except faults.FatalFault:
        raise
    except (faults.InjectedFault, AttributeError, KeyError, TypeError,
            ValueError):
        return None                          # uninstantiable candidate
    return {e.label: {"candidate": e.candidate,
                      "rank_source": e.rank_source}
            for e in plan.entries}


def warm_from_plan(cfg: ModelConfig, *,
                   machine: MachineDescription = TPU_V5E,
                   max_len: int = 512, page_size: int = 0,
                   store: Optional[PlanStore] = None,
                   cache: Optional[DispatchCache] = None,
                   strict: bool = False,
                   dispatch_store: Optional[ArtifactStore] = None
                   ) -> Optional[Dict[str, Any]]:
    """The plan-backed warm-up: load, validate, check staleness, freeze.
    ``None`` on any miss — the caller (``warm_kernel_dispatch``) falls
    back online.

    Staleness is the one *loud* miss: when the plan's recorded dispatch-
    table digests disagree with the tables on this host (``dispatch_store``,
    default: the cache's attached store, else the environment-resolved
    artifact root), the plan's frozen picks may no longer match what the
    tiers would resolve.  Default: emit a :class:`StalePlanWarning` and
    return ``None`` (online warm-up re-resolves against the fresh tables).
    ``strict=True``: raise :class:`StalePlanError` — the engine's
    ``--strict-plans`` refusal, for fleets where serving a pick the tuner
    disowned must fail deployment rather than degrade silently."""
    plan = load_serve_plan(cfg, machine=machine, store=store,
                           max_len=max_len, page_size=page_size)
    if plan is None or not plan.entries:
        return None
    if dispatch_store is None:
        dispatch_store = (cache.store
                          if cache is not None and cache.store is not None
                          else _resolve_dispatch_store())
    stale = plan_staleness(plan, machine=machine, store=dispatch_store)
    if stale:
        detail = ", ".join(
            f"{fam} (plan={rec[:12] or 'none'} "
            f"host={cur[:12] or 'none'})"
            for fam, (rec, cur) in sorted(stale.items()))
        msg = (f"serve plan for {cfg.name}/{machine.name} is STALE: "
               f"dispatch tables changed under it for {detail}; "
               f"rebuild with scripts/plan_artifacts.py")
        if strict:
            raise StalePlanError(msg)
        warnings.warn(msg, StalePlanWarning, stacklevel=2)
        return None                          # loud miss: online warm-up
    return apply_serve_plan(plan, machine=machine, cache=cache)
