"""Versioned, byte-deterministic serialization for serve-plan artifacts.

A *serve plan* is the shippable half of serving warm-up: the traced
``(family, machine, data)`` warm set of one model config together with the
candidate each triple resolved to and the ranking tier that decided it
(``rank_source``).  Built offline by ``scripts/plan_artifacts.py``, shipped
next to the dispatch tables, and fed straight to
``DispatchCache.freeze_resolved`` at engine start — a plan-backed process
performs zero online tree enumerations.

Each entry embeds the candidate's full :class:`KernelPlan` (via
:mod:`repro.artifacts.serde`), so instantiating the kernel callables needs
neither the tree nor the dispatch table to be present on the serving host.

Format policy (same as the dispatch artifacts, recorded in ROADMAP.md):
every payload embeds ``PLAN_FORMAT_VERSION``; readers treat a version
mismatch, unreadable file, or mangled payload as a **cache miss** — serving
falls back to online warm-up, never errors.  Bump the version on any schema
*or semantic* change.  Plans are never migrated; they are rebuilt by
``scripts/plan_artifacts.py``.

Version history:
  1 — traced warm set + resolved candidates + rank_source (PR 5).
  2 — ``page_size`` joins the plan identity (PR 6): the paged serving
      engine's attention bucket keys carry the KV block size, so a plan
      traced for one block size (or the dense layout, ``page_size=0``)
      must read as a miss for any other.
  3 — ``table_digests`` (PR 8): per resolved kernel family, the digest of
      the dispatch-table artifact the plan's picks were resolved against
      (empty string = no table existed).  ``scripts/tune_artifacts.py``
      rewrites dispatch tables in place, silently invalidating the frozen
      picks of every plan built against the old ranking; the digests let
      engine start *detect* that staleness (:func:`repro.plans.loader.
      plan_staleness`) and warn — or refuse, under ``--strict-plans`` —
      instead of serving stale picks quietly.  A v2 plan reads as a miss,
      never an error, per the standing artifact policy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Tuple

from ..artifacts import serde as artifact_serde
from ..artifacts.serde import ArtifactFormatError
from ..core.select import Candidate

PLAN_FORMAT_VERSION = 3

_RANK_SOURCES = ("measured", "symbolic", "cold")


@dataclass(frozen=True)
class PlanEntry:
    """One warm-set member: the triple, its resolution, and attribution."""

    label: str
    family: str
    data: Tuple[Tuple[str, int], ...]        # sorted items
    sites: Tuple[str, ...]                   # abstract call sites (trace.py)
    candidate: Candidate
    rank_source: str                         # "measured"|"symbolic"|"cold"

    def data_dict(self) -> Dict[str, int]:
        return dict(self.data)


@dataclass(frozen=True)
class ServePlan:
    """A portable serve-plan artifact (deserialized form)."""

    config: str                              # ModelConfig.name
    machine: str                             # MachineDescription.name
    machine_bindings: Dict[str, int]         # stale-machine guard
    max_len: int                             # trace parameter the plan is for
    page_size: int                           # paged KV block size (0 = dense)
    include_train: bool
    entries: Tuple[PlanEntry, ...]
    #: family -> digest of the dispatch table the picks were resolved
    #: against ("" = no table existed at build time); the staleness record
    table_digests: Tuple[Tuple[str, str], ...] = ()

    def digest(self) -> str:
        return artifact_serde.digest(plan_to_obj(self))

    def table_digest_map(self) -> Dict[str, str]:
        return dict(self.table_digests)


# ---------------------------------------------------------------------------
# ServePlan <-> canonical JSON object
# ---------------------------------------------------------------------------

def _candidate_to_obj(c: Candidate) -> Dict[str, Any]:
    return {
        "leaf_index": int(c.leaf_index),
        "plan": artifact_serde.plan_to_obj(c.plan),
        "assignment": {k: int(v) for k, v in sorted(c.assignment.items())},
        "score": float(c.score),
    }


def _obj_to_candidate(obj: Mapping[str, Any]) -> Candidate:
    return Candidate(
        leaf_index=int(obj["leaf_index"]),
        plan=artifact_serde.obj_to_plan(obj["plan"]),
        assignment={str(k): int(v) for k, v in obj["assignment"].items()},
        score=float(obj["score"]),
    )


def entry_to_obj(e: PlanEntry) -> Dict[str, Any]:
    return {
        "label": e.label,
        "family": e.family,
        "data": {k: int(v) for k, v in e.data},
        "sites": list(e.sites),
        "candidate": _candidate_to_obj(e.candidate),
        "rank_source": e.rank_source,
    }


def obj_to_entry(obj: Mapping[str, Any]) -> PlanEntry:
    source = str(obj["rank_source"])
    if source not in _RANK_SOURCES:
        raise ArtifactFormatError(f"unknown rank_source {source!r}")
    return PlanEntry(
        label=str(obj["label"]),
        family=str(obj["family"]),
        data=tuple(sorted((str(k), int(v))
                          for k, v in obj["data"].items())),
        sites=tuple(str(s) for s in obj["sites"]),
        candidate=_obj_to_candidate(obj["candidate"]),
        rank_source=source,
    )


def plan_to_obj(plan: ServePlan) -> Dict[str, Any]:
    """Canonical JSON object; ``artifacts.serde.dumps`` of it is byte-stable
    (sorted keys, int-coerced values, deterministic entry order from the
    tracer)."""
    return {
        "format": PLAN_FORMAT_VERSION,
        "kind": "serve_plan",
        "config": plan.config,
        "machine": plan.machine,
        "machine_bindings": {k: int(v)
                             for k, v in plan.machine_bindings.items()},
        "max_len": int(plan.max_len),
        "page_size": int(plan.page_size),
        "include_train": bool(plan.include_train),
        "table_digests": {k: str(v) for k, v in plan.table_digests},
        "entries": [entry_to_obj(e) for e in plan.entries],
    }


def obj_to_plan(obj: Mapping[str, Any]) -> ServePlan:
    """Parse a payload; raises :class:`ArtifactFormatError` (or the usual
    mangled-payload TypeError/KeyError/ValueError family) on anything
    structurally off — loaders catch and treat it as a miss."""
    if obj.get("kind") != "serve_plan":
        raise ArtifactFormatError(
            f"not a serve-plan artifact: {obj.get('kind')!r}")
    if obj.get("format") != PLAN_FORMAT_VERSION:
        raise ArtifactFormatError(
            f"serve-plan format {obj.get('format')!r} != "
            f"{PLAN_FORMAT_VERSION}")
    return ServePlan(
        config=str(obj["config"]),
        machine=str(obj["machine"]),
        machine_bindings={str(k): int(v)
                          for k, v in obj["machine_bindings"].items()},
        max_len=int(obj["max_len"]),
        page_size=int(obj["page_size"]),
        include_train=bool(obj["include_train"]),
        entries=tuple(obj_to_entry(e) for e in obj["entries"]),
        table_digests=tuple(sorted((str(k), str(v)) for k, v
                                   in obj["table_digests"].items())),
    )


def dumps(plan: ServePlan) -> str:
    """Canonical byte-stable JSON text for a serve plan."""
    return artifact_serde.dumps(plan_to_obj(plan))
