"""Filesystem store for serve-plan artifacts.

Layout (canonical bytes from :mod:`repro.plans.serde`):

    <root>/plans/<config>/serve-v<V>-<machine>.json

``root`` resolution matches the dispatch artifacts (explicit argument >
``REPRO_ARTIFACT_DIR`` env var > ``./artifacts``) so a deployment ships one
directory: dispatch tables, trees, and serve plans travel together to every
host of the mesh.  Loads are forgiving by design — missing file, unreadable
JSON, version mismatch, or a mangled payload all return ``None`` (cache
miss: the engine falls back to online warm-up); only writes raise.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Optional

from ..artifacts.serde import ArtifactFormatError
# one source of truth for the root-resolution rule and the atomic-write /
# forgiving-read machinery: serve plans live under the same root and follow
# the same IO discipline as trees/dispatch tables
from ..artifacts.store import (_DEFAULT_ROOT, _ENV_ROOT, atomic_write_text,
                               read_json_dict)
from . import serde


class PlanStore:
    def __init__(self, root: str | os.PathLike | None = None):
        self.root = Path(root or os.environ.get(_ENV_ROOT, _DEFAULT_ROOT))

    def plan_path(self, config_name: str, machine_name: str) -> Path:
        return (self.root / "plans" / config_name /
                f"serve-v{serde.PLAN_FORMAT_VERSION}-{machine_name}.json")

    def save_plan(self, plan: serde.ServePlan) -> Path:
        return atomic_write_text(self.plan_path(plan.config, plan.machine),
                                 serde.dumps(plan))

    def load_plan(self, config_name: str,
                  machine_name: str) -> Optional[serde.ServePlan]:
        payload = read_json_dict(self.plan_path(config_name, machine_name),
                                 fault_site="plan.read")
        if payload is None:
            return None
        try:
            return serde.obj_to_plan(payload)
        except (ArtifactFormatError, AttributeError, KeyError, TypeError,
                ValueError):
            return None                      # mangled/stale == cache miss

    def __repr__(self) -> str:
        return f"PlanStore({str(self.root)!r})"


def resolve_env_store() -> Optional[PlanStore]:
    """The environment-resolved store, or ``None`` when the artifact root
    does not exist (mirrors ``dispatch._resolve_env_store``)."""
    root = os.environ.get(_ENV_ROOT, _DEFAULT_ROOT)
    return PlanStore(root) if os.path.isdir(root) else None
