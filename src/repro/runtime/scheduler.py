"""Host-side async serving scheduler: admission, chunked prefill, preemption.

``ServeEngine.step`` delegates every *decision* to :class:`Scheduler.tick`,
which returns a :class:`TickPlan` of tensor work to perform; the engine
only executes it.  One tick is one engine step:

1. **decode-priority block top-up** — every sequence in decode owns the KV
   block its next token writes into before anything else runs; when the
   pool is exhausted, the *youngest-admitted* running sequence is preempted
   by eviction (its blocks return to the pool, its request re-enters the
   queue front for recompute — generated tokens are kept and re-prefilled
   as part of the prompt).
2. **admission control** — strict FIFO.  A request is admitted only when a
   decode-batch slot is free AND the pool has head-room for its whole
   prompt plus one decode block plus a watermark of ``watermark_blocks``
   (default ``max_batch``: one block of decode head-room per potential
   decode row).  This is the long-prompt guard: a prompt that fits in a
   slot but not in the pool waits in the queue instead of being admitted
   and then starving decode via preemption storms.
3. **chunked prefill** — at most one prompt chunk per tick (the oldest
   admitted sequence still prefilling), so prefill work is interleaved
   with decode steps and decode latency stays bounded under prompt
   bursts.  Chunk lengths are quantized (full ``prefill_chunk``-sized
   chunks, then a power-of-two decomposition of the remainder) so the
   compiled chunk-shape set is O(log ``prefill_chunk``) instead of one
   shape per prompt length.

Starvation bound: FIFO admission + oldest-first prefill + decode running
every tick give every admitted sequence progress within
:meth:`Scheduler.progress_bound` ticks (tests assert it).  Preemption
resets a sequence's clock — it re-enters at the queue *front* (it is by
construction older than everything still queued, so global FIFO order is
preserved).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np

from .kv_pool import PagedKVPool


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SeqState:
    """One admitted sequence: its request plus pool/slot bookkeeping."""

    req: Request
    slot: int
    target: np.ndarray                   # tokens to prefill (prompt [+ out])
    admitted_at: int
    last_progress: int
    blocks: List[int] = field(default_factory=list)
    filled: int = 0                      # prefilled positions
    pos: int = 0                         # cache positions written

    @property
    def prefilling(self) -> bool:
        return self.filled < len(self.target)


@dataclass
class SchedStats:
    admissions: int = 0
    preemptions: int = 0
    prefill_chunks: int = 0
    decode_ticks: int = 0
    admission_waits: int = 0             # head-of-line blocked on head-room


@dataclass
class TickPlan:
    """The tensor work one engine step must perform, in order."""

    admitted: List[SeqState] = field(default_factory=list)
    prefill: Optional[Tuple[SeqState, int, int]] = None  # (seq, start, len)
    decode: List[SeqState] = field(default_factory=list)
    preempted: List[SeqState] = field(default_factory=list)


class Scheduler:
    def __init__(self, pool: PagedKVPool, *, max_batch: int, max_len: int,
                 prefill_chunk: int = 32,
                 watermark_blocks: Optional[int] = None):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1: {prefill_chunk}")
        self.pool = pool
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.watermark = (max_batch if watermark_blocks is None
                          else watermark_blocks)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[SeqState]] = [None] * max_batch
        self.ticks = 0
        self.stats = SchedStats()

    # -- client side ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request.  Rejects up front what could never be served:
        the prompt plus the full generation budget must fit both the serve
        window and the pool."""
        total = len(req.prompt) + req.max_new
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}")
        if self.pool.blocks_for(total) > self.pool.capacity:
            raise ValueError(
                f"request {req.rid}: needs "
                f"{self.pool.blocks_for(total)} blocks, pool capacity is "
                f"{self.pool.capacity}")
        self.queue.append(req)

    def running(self) -> List[SeqState]:
        return [s for s in self.slots if s is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def progress_bound(self) -> int:
        """Ticks within which every *admitted, non-preempted* sequence is
        guaranteed progress: decode rows progress every tick; a prefilling
        sequence waits at most for every older sequence's remaining chunks
        (each prompt is at most ``ceil(max_len/prefill_chunk)`` full chunks
        plus the power-of-two tail of its remainder)."""
        chunks_per_seq = (-(-self.max_len // self.prefill_chunk)
                          + max(1, self.prefill_chunk).bit_length())
        return self.max_batch * chunks_per_seq + 1

    # -- the tick -------------------------------------------------------------
    def tick(self) -> TickPlan:
        t = self.ticks
        self.ticks += 1
        plan = TickPlan()

        # 1. decode priority: secure the write block of every decode row,
        # evicting the youngest running sequence when the pool runs dry
        for seq in sorted((s for s in self.running() if not s.prefilling),
                          key=lambda s: (s.admitted_at, s.req.rid)):
            if self.slots[seq.slot] is not seq:
                continue                       # evicted by an older row
            while self.pool.blocks_for(seq.pos + 1) > len(seq.blocks):
                got = self.pool.alloc(1)
                if got is not None:
                    seq.blocks.extend(got)
                    continue
                victim = self._youngest_running()
                self._preempt(victim)
                plan.preempted.append(victim)
                if victim is seq:
                    break
        decoding = [s for s in self.running() if not s.prefilling]

        # 2. FIFO admission with KV head-room (the long-prompt guard).
        # Head-room is judged against free blocks MINUS what running
        # sequences have claimed but not yet allocated (admitted prompts
        # only take blocks as their chunks prefill) — otherwise a long
        # admitted prompt is invisible to the next admission.
        for slot in range(self.max_batch):
            if not self.queue:
                break
            if self.slots[slot] is not None:
                continue
            req = self.queue[0]
            target = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out, np.int32)]).astype(np.int32)
            needed = self.pool.blocks_for(len(target) + 1)
            committed = sum(
                max(0, self.pool.blocks_for(len(s.target) + 1)
                    - len(s.blocks))
                for s in self.running())
            reserve = self.watermark if self.running() else 0
            if self.pool.num_free - committed < needed + reserve:
                self.stats.admission_waits += 1
                break                          # strict FIFO: head blocks
            self.queue.popleft()
            seq = SeqState(req=req, slot=slot, target=target,
                           admitted_at=t, last_progress=t)
            self.slots[slot] = seq
            plan.admitted.append(seq)
            self.stats.admissions += 1

        # 3. one prefill chunk: oldest admitted sequence still prefilling
        for seq in sorted((s for s in self.running() if s.prefilling),
                          key=lambda s: (s.admitted_at, s.req.rid)):
            c = self._chunk_len(len(seq.target) - seq.filled)
            need = self.pool.blocks_for(seq.filled + c) - len(seq.blocks)
            if need > 0:
                got = self.pool.alloc(need)
                if got is None:
                    continue                   # pool tight: wait for retires
                seq.blocks.extend(got)
            plan.prefill = (seq, seq.filled, c)
            break

        plan.decode = decoding
        if decoding:
            self.stats.decode_ticks += 1
        return plan

    # -- engine feedback ------------------------------------------------------
    def note_prefill(self, seq: SeqState, chunk: int) -> None:
        seq.filled += chunk
        seq.pos = seq.filled
        seq.last_progress = self.ticks
        self.stats.prefill_chunks += 1

    def note_decode(self, seq: SeqState) -> None:
        seq.pos += 1
        seq.last_progress = self.ticks

    def retire(self, seq: SeqState) -> None:
        """Copy-free retirement: blocks go back to the free list, the slot
        frees for the next admission.  Nothing on the device moves."""
        if seq.blocks:
            self.pool.free(seq.blocks)
        seq.blocks = []
        self.slots[seq.slot] = None

    # -- internals ------------------------------------------------------------
    def _chunk_len(self, remaining: int) -> int:
        """Full chunks of ``prefill_chunk``; the tail decomposes into
        powers of two (largest first) to bound the compiled shape set."""
        if remaining >= self.prefill_chunk:
            return self.prefill_chunk
        return 1 << (remaining.bit_length() - 1)

    def _youngest_running(self) -> SeqState:
        return max(self.running(),
                   key=lambda s: (s.admitted_at, s.req.rid))

    def _preempt(self, seq: SeqState) -> None:
        """Evict by recompute: free the blocks, keep the generated tokens,
        and requeue at the *front* (the victim predates everything still
        queued, so FIFO order is preserved).  On re-admission the prompt
        plus generated tokens re-prefill and decode continues."""
        if seq.blocks:
            self.pool.free(seq.blocks)
        seq.blocks = []
        self.slots[seq.slot] = None
        self.queue.appendleft(seq.req)
        self.stats.preemptions += 1
