"""Host-side async serving scheduler: admission, chunked prefill, preemption.

``ServeEngine`` delegates every *decision* to :class:`Scheduler.tick`,
which returns a :class:`TickPlan` of tensor work to perform; the engine
only executes it.  One tick is one engine dispatch:

1. **decode-priority block top-up** — every sequence in decode owns the KV
   block its next token writes into before anything else runs; when the
   pool is exhausted, the *youngest-admitted* running sequence is preempted
   by eviction (its blocks return to the pool, its request re-enters the
   queue front for recompute — generated tokens are kept and re-prefilled
   as part of the prompt).  A write block that is *shared* (refcount > 1:
   prefix-mapped by another sequence or pinned by the prefix index) is
   replaced copy-on-write: a fresh block is allocated, the tick plan
   records a device-side block copy, and only the private copy is written.
2. **admission control** — strict FIFO.  A request is admitted only when a
   decode-batch slot is free AND the pool has head-room for its whole
   prompt plus one decode block plus a watermark of ``watermark_blocks``
   (default ``max_batch``: one block of decode head-room per potential
   decode row).  With ``prefix_sharing`` the pool's prefix index is probed
   first: prompt blocks already resident (from a live or recently-retired
   sequence) are *mapped* instead of recomputed — they join the block
   table at an elevated refcount, prefill starts past them, and head-room
   only has to cover the unmatched tail.  Idle cached blocks count toward
   head-room (the allocator reclaims them LRU on demand).
3. **chunked prefill** — at most one prompt chunk per tick (the oldest
   admitted sequence still prefilling), so prefill work is interleaved
   with decode steps and decode latency stays bounded under prompt
   bursts.  Chunk lengths are quantized (full ``prefill_chunk``-sized
   chunks, then a power-of-two decomposition of the remainder) so the
   compiled chunk-shape set is O(log ``prefill_chunk``) instead of one
   shape per prompt length.  Shared blocks in the chunk's write range are
   CoW-replaced exactly like decode write blocks; as each *full* block of
   the target fills, it is registered in the prefix index for future
   requests to map.

The scheduler plans against **dispatch-time** state: ``note_prefill`` /
``note_decode`` advance ``filled``/``pos`` when work is *dispatched*, not
when it completes, so under async overlap (engine ``async_depth > 1``) the
next tick is planned against positions the in-flight tick is already
writing.  Committed *outputs* (``req.out``) land later, at the engine's
commit barrier; the **dispatch guard** below keeps speculation bounded:
a sequence stops decoding once the outputs it has in flight reach its
``max_new`` budget (EOS is only detectable at commit, so a sequence may
overshoot an EOS by up to the pipeline depth — commit truncates).

Starvation bound: FIFO admission + oldest-first prefill + decode running
every tick give every admitted sequence progress within
:meth:`Scheduler.progress_bound` ticks (tests assert it).  Preemption
resets a sequence's clock — it re-enters at the queue *front* (it is by
construction older than everything still queued, so global FIFO order is
preserved) — and marks the evicted ``SeqState`` **dead** so the engine
discards its uncommitted in-flight tokens (greedy decode regenerates them
deterministically after re-admission).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np

from ..obs import recorder as obs
from ..obs.events import AdmissionDecision
from . import faults
from .kv_pool import PREFIX_ROOT, PagedKVPool


class RequestError(ValueError):
    """Structured per-request failure: what was rejected and why.

    Subclasses ``ValueError`` so pre-existing callers (and tests) that
    catch the scheduler's validation errors keep working.  Carries a
    machine-readable ``code`` — ``"too_long"`` / ``"over_capacity"`` /
    ``"empty_prompt"`` / ``"bad_max_new"`` (validation, raised from
    ``submit``), ``"queue_full"`` (load shed, *returned*, never raised) or
    ``"deadline"`` (TTL cancellation, attached to the request at tick
    time) — plus a ``retry_after_ticks`` hint where retrying can help
    (shed/deadline) and ``None`` where it cannot (validation)."""

    def __init__(self, code: str, message: str, *, rid: Optional[int] = None,
                 retry_after_ticks: Optional[int] = None):
        super().__init__(message)
        self.code = code
        self.message = message
        self.rid = rid
        self.retry_after_ticks = retry_after_ticks

    def __repr__(self) -> str:
        return (f"RequestError({self.code!r}, rid={self.rid}, "
                f"retry_after_ticks={self.retry_after_ticks})")


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = field(default_factory=list)
    done: bool = False
    #: absolute deadline on the scheduler's clock (None: no TTL).  Expired
    #: requests are cancelled at tick time — queued or running — keeping
    #: whatever output already committed.
    deadline: Optional[float] = None
    #: structured failure when the request ended abnormally (shed,
    #: cancelled, rejected); ``done`` is True whenever this is set.
    error: Optional[RequestError] = None


@dataclass
class SeqState:
    """One admitted sequence: its request plus pool/slot bookkeeping."""

    req: Request
    slot: int
    target: np.ndarray                   # tokens to prefill (prompt [+ out])
    admitted_at: int
    last_progress: int
    blocks: List[int] = field(default_factory=list)
    filled: int = 0                      # prefilled positions (dispatched)
    pos: int = 0                         # cache positions written (dispatched)
    prompt_len: int = 0                  # len(req.prompt) at admission
    chain_hash: int = PREFIX_ROOT        # prefix-index chain over registered
    registered: int = 0                  # full target blocks registered
    dead: bool = False                   # preempted: drop uncommitted tokens

    @property
    def prefilling(self) -> bool:
        return self.filled < len(self.target)

    @property
    def dispatched_out(self) -> int:
        """Output tokens dispatched (committed + in flight): the prefill
        seed token plus one per decode dispatch."""
        if self.prefilling:
            return len(self.target) - self.prompt_len
        return self.pos - self.prompt_len + 1


@dataclass
class SchedStats:
    admissions: int = 0
    preemptions: int = 0
    prefill_chunks: int = 0
    prefill_tokens: int = 0              # token positions actually computed
    decode_ticks: int = 0
    admission_waits: int = 0             # head-of-line blocked on head-room
    shed: int = 0                        # submits refused by the queue bound
    cancelled: int = 0                   # requests expired by their deadline
    poisoned: int = 0                    # sequences preempted after a fault


@dataclass
class TickPlan:
    """The tensor work one engine step must perform, in order.  ``cow``
    copies run first — a shared block must be duplicated device-side
    before this tick's prefill/decode writes into the private copy.
    ``cow_owners[i]`` is the sequence whose table entry ``cow[i]``
    rewrites — fault attribution for the engine's degrade path."""

    admitted: List[SeqState] = field(default_factory=list)
    cow: List[Tuple[int, int]] = field(default_factory=list)  # (src, dst)
    cow_owners: List["SeqState"] = field(default_factory=list)
    prefill: Optional[Tuple[SeqState, int, int]] = None  # (seq, start, len)
    decode: List[SeqState] = field(default_factory=list)
    preempted: List[SeqState] = field(default_factory=list)
    cancelled: List[Request] = field(default_factory=list)


class Scheduler:
    def __init__(self, pool: PagedKVPool, *, max_batch: int, max_len: int,
                 prefill_chunk: int = 32,
                 watermark_blocks: Optional[int] = None,
                 prefix_sharing: bool = False,
                 max_queue: Optional[int] = None,
                 clock: faults.Clock = faults.default_clock):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1: {prefill_chunk}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1: {max_queue}")
        self.pool = pool
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing
        self.watermark = (max_batch if watermark_blocks is None
                          else watermark_blocks)
        self.max_queue = max_queue
        self.clock = clock
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[SeqState]] = [None] * max_batch
        self.ticks = 0
        self.stats = SchedStats()

    def _emit(self, action: str, rid: int, slot: int = -1) -> None:
        """Trace one scheduling decision; every action maps 1:1 onto its
        :class:`SchedStats` counter (``admit``/``wait``/``shed``/
        ``preempt``/``poison``/``cancel``), so a trace reconstructs the
        stats exactly.  Tick ids come from the recorder's cursor — the
        engine advances it alongside the fault injector's, so scheduler,
        fault, and dispatch events join on the same tick numbering.  One
        module-global load when tracing is off."""
        rec = obs._recorder
        if rec is not None:
            rec.emit(AdmissionDecision(tick=rec.tick, action=action,
                                       rid=int(rid), slot=int(slot),
                                       queue_depth=len(self.queue)))

    # -- client side ----------------------------------------------------------
    def submit(self, req: Request) -> Optional[RequestError]:
        """Queue a request.

        *Malformed* requests — empty prompt, non-positive generation
        budget, or a prompt + budget that could never fit the serve window
        or the pool — **raise** a :class:`RequestError` (they are caller
        bugs; retrying cannot help).  A well-formed request arriving while
        the queue is at ``max_queue`` is **load-shed**: it is marked done
        with a ``queue_full`` error carrying a retry-after hint (the ticks
        the current queue needs to drain, roughly), and that error is
        *returned* — overload is an operating condition, not an exception."""
        if len(req.prompt) == 0:
            raise RequestError("empty_prompt",
                               f"request {req.rid}: empty prompt",
                               rid=req.rid)
        if req.max_new < 1:
            raise RequestError(
                "bad_max_new",
                f"request {req.rid}: max_new must be >= 1: {req.max_new}",
                rid=req.rid)
        total = len(req.prompt) + req.max_new
        if total > self.max_len:
            raise RequestError(
                "too_long",
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}",
                rid=req.rid)
        if self.pool.blocks_for(total) > self.pool.capacity:
            raise RequestError(
                "over_capacity",
                f"request {req.rid}: needs "
                f"{self.pool.blocks_for(total)} blocks, pool capacity is "
                f"{self.pool.capacity}",
                rid=req.rid)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            err = RequestError(
                "queue_full",
                f"request {req.rid}: queue at max_queue={self.max_queue}",
                rid=req.rid,
                retry_after_ticks=self._drain_hint())
            req.error = err
            req.done = True
            self.stats.shed += 1
            self._emit("shed", req.rid)
            return err
        self.queue.append(req)
        return None

    def _drain_hint(self) -> int:
        """Rough ticks until the head of today's queue could admit: one
        chunk-quantized prefill pass per queued prompt ahead of it."""
        per_req = max(1, -(-self.max_len // self.prefill_chunk))
        return max(1, len(self.queue) * per_req // max(1, self.max_batch))

    def running(self) -> List[SeqState]:
        return [s for s in self.slots if s is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def progress_bound(self) -> int:
        """Ticks within which every *admitted, non-preempted* sequence is
        guaranteed progress: decode rows progress every tick; a prefilling
        sequence waits at most for every older sequence's remaining chunks
        (each prompt is at most ``ceil(max_len/prefill_chunk)`` full chunks
        plus the power-of-two tail of its remainder)."""
        chunks_per_seq = (-(-self.max_len // self.prefill_chunk)
                          + max(1, self.prefill_chunk).bit_length())
        return self.max_batch * chunks_per_seq + 1

    # -- the tick -------------------------------------------------------------
    def tick(self) -> TickPlan:
        t = self.ticks
        self.ticks += 1
        plan = TickPlan()

        # 0. deadline sweep: expire TTLs *before* planning work, so a
        # cancelled sequence neither claims blocks nor joins the decode
        # batch this tick.  Running victims keep their committed output
        # (a timeout is a partial answer, not a void one).
        self._expire_deadlines(plan)

        # 1. decode priority: secure a *private* write block for every
        # decode row — allocating the block its next token needs and
        # copy-on-write-replacing it if shared — evicting the youngest
        # running sequence whenever the pool runs dry.  Rows whose
        # dispatched outputs already cover max_new sit out (async overlap
        # must not speculate past the generation budget: the fixed-width
        # block table and the serve window are sized for max_new).
        for seq in sorted((s for s in self.running()
                           if not s.prefilling
                           and s.dispatched_out < s.req.max_new),
                          key=lambda s: (s.admitted_at, s.req.rid)):
            if self.slots[seq.slot] is not seq:
                continue                       # evicted by an older row
            while True:
                if self.pool.blocks_for(seq.pos + 1) > len(seq.blocks):
                    got = self.pool.alloc(1)
                    if got is not None:
                        seq.blocks.extend(got)
                        continue
                else:
                    wb = seq.pos // self.pool.page_size
                    if not self.pool.is_shared(seq.blocks[wb]):
                        break
                    got = self.pool.alloc(1)
                    if got is not None:
                        self._cow(plan, seq, wb, got[0])
                        continue
                victim = self._youngest_running()
                self._preempt(victim)
                self._emit("preempt", victim.req.rid, victim.slot)
                plan.preempted.append(victim)
                if victim is seq:
                    break
        decoding = [s for s in self.running()
                    if not s.prefilling
                    and s.dispatched_out < s.req.max_new]

        # 2. FIFO admission with KV head-room (the long-prompt guard).
        # Head-room is judged against free blocks MINUS what running
        # sequences have claimed but not yet allocated (admitted prompts
        # only take blocks as their chunks prefill) — otherwise a long
        # admitted prompt is invisible to the next admission.  Idle cached
        # prefix blocks count as free-in-waiting (alloc reclaims them),
        # and blocks the prefix index already holds for this prompt don't
        # need head-room at all: the probe maps them instead.
        for slot in range(self.max_batch):
            if not self.queue:
                break
            if self.slots[slot] is not None:
                continue
            req = self.queue[0]
            target = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out, np.int32)]).astype(np.int32)
            probe: List[int] = []
            if self.prefix_sharing:
                probe, _, _ = self.pool.match_prefix(target, commit=False)
            needed = self.pool.blocks_for(len(target) + 1) - len(probe)
            committed = sum(
                max(0, self.pool.blocks_for(len(s.target) + 1)
                    - len(s.blocks))
                for s in self.running())
            reserve = self.watermark if self.running() else 0
            avail = (self.pool.num_free
                     + max(0, self.pool.num_reclaimable - len(probe)))
            if avail - committed < needed + reserve:
                self.stats.admission_waits += 1
                self._emit("wait", req.rid)
                break                          # strict FIFO: head blocks
            self.queue.popleft()
            seq = SeqState(req=req, slot=slot, target=target,
                           admitted_at=t, last_progress=t,
                           prompt_len=len(req.prompt))
            if self.prefix_sharing:
                blocks, matched, chash = self.pool.match_prefix(target)
                seq.blocks = list(blocks)
                seq.filled = seq.pos = matched
                seq.chain_hash = chash
                seq.registered = matched // self.pool.page_size
            self.slots[slot] = seq
            plan.admitted.append(seq)
            self.stats.admissions += 1
            self._emit("admit", req.rid, slot)

        # 3. one prefill chunk: oldest admitted sequence still prefilling.
        # The chunk's write range must be private: shared blocks in it are
        # CoW-replaced, and the new-block + CoW-copy allocation is
        # all-or-nothing (pool tight: wait for retires).
        for seq in sorted((s for s in self.running() if s.prefilling),
                          key=lambda s: (s.admitted_at, s.req.rid)):
            c = self._chunk_len(len(seq.target) - seq.filled)
            ps = self.pool.page_size
            shared = [i for i in range(seq.filled // ps,
                                       min(-(-(seq.filled + c) // ps),
                                           len(seq.blocks)))
                      if self.pool.is_shared(seq.blocks[i])]
            need = self.pool.blocks_for(seq.filled + c) - len(seq.blocks)
            got = self.pool.alloc(max(0, need) + len(shared))
            if got is None:
                continue                       # pool tight: wait for retires
            for i, dst in zip(shared, got):
                self._cow(plan, seq, i, dst)
            seq.blocks.extend(got[len(shared):])
            plan.prefill = (seq, seq.filled, c)
            break

        plan.decode = decoding
        if decoding:
            self.stats.decode_ticks += 1
        return plan

    # -- engine feedback ------------------------------------------------------
    def note_prefill(self, seq: SeqState, chunk: int) -> None:
        seq.filled += chunk
        seq.pos = seq.filled
        seq.last_progress = self.ticks
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += chunk
        if self.prefix_sharing:
            # register each newly-full block of the target so future
            # prompts sharing this prefix map it instead of recomputing
            ps = self.pool.page_size
            while (seq.registered + 1) * ps <= seq.filled:
                i = seq.registered
                seq.chain_hash = self.pool.register_prefix(
                    seq.chain_hash, seq.target[i * ps:(i + 1) * ps],
                    seq.blocks[i])
                seq.registered += 1

    def note_decode(self, seq: SeqState) -> None:
        seq.pos += 1
        seq.last_progress = self.ticks

    def retire(self, seq: SeqState) -> None:
        """Copy-free retirement: the sequence drops its refcounts and the
        slot frees for the next admission.  Nothing on the device moves;
        blocks the prefix index pinned stay resident (the "recently
        retired" cache) until LRU reclaim, the rest return to the free
        list."""
        if seq.blocks:
            self.pool.free(seq.blocks)
        seq.blocks = []
        self.slots[seq.slot] = None

    # -- robustness -----------------------------------------------------------
    def _expire_deadlines(self, plan: TickPlan) -> None:
        """Cancel every queued or running request whose deadline passed.
        One clock read per tick; requests without deadlines cost one
        attribute test each."""
        if not self.queue and not any(s is not None for s in self.slots):
            return
        now: Optional[float] = None
        for req in list(self.queue):
            if req.deadline is None:
                continue
            now = self.clock() if now is None else now
            if now >= req.deadline:
                self.queue.remove(req)
                self._cancel(req, plan)
        for seq in self.running():
            if seq.req.deadline is None:
                continue
            now = self.clock() if now is None else now
            if now >= seq.req.deadline:
                if seq.blocks:
                    self.pool.free(seq.blocks)
                seq.blocks = []
                seq.dead = True              # drop its uncommitted in-flight
                self.slots[seq.slot] = None
                self._cancel(seq.req, plan)

    def _cancel(self, req: Request, plan: TickPlan) -> None:
        req.error = RequestError("deadline",
                                 f"request {req.rid}: deadline exceeded",
                                 rid=req.rid, retry_after_ticks=1)
        req.done = True
        plan.cancelled.append(req)
        self.stats.cancelled += 1
        self._emit("cancel", req.rid)

    def poison(self, seq: SeqState) -> bool:
        """Reconcile a sequence whose in-flight work faulted: preempt it by
        recompute (the PR 6 eviction path — committed tokens kept, request
        requeued at the front, state marked dead so the engine drops its
        uncommitted tokens).  Greedy decode regenerates the lost tokens
        deterministically after re-admission, so surviving output is
        token-exact.  Returns False when the sequence already left its slot
        (retired/preempted/cancelled in the meantime) — poisoning is then
        moot."""
        if seq.dead or self.slots[seq.slot] is not seq:
            return False
        self._preempt(seq)
        self.stats.preemptions -= 1          # reattribute: fault, not pressure
        self.stats.poisoned += 1
        self._emit("poison", seq.req.rid, seq.slot)
        return True

    # -- internals ------------------------------------------------------------
    def _cow(self, plan: TickPlan, seq: SeqState, i: int, dst: int) -> None:
        """Replace block-table entry ``i`` with freshly-allocated ``dst``:
        plan the device copy, then drop this sequence's ref on the shared
        source (other owners keep it)."""
        src = seq.blocks[i]
        plan.cow.append((src, dst))
        plan.cow_owners.append(seq)
        seq.blocks[i] = dst
        self.pool.free([src])
        self.pool.stats.cow_copies += 1

    def _chunk_len(self, remaining: int) -> int:
        """Full chunks of ``prefill_chunk``; the tail decomposes into
        powers of two (largest first) to bound the compiled shape set."""
        if remaining >= self.prefill_chunk:
            return self.prefill_chunk
        return 1 << (remaining.bit_length() - 1)

    def _youngest_running(self) -> SeqState:
        return max(self.running(),
                   key=lambda s: (s.admitted_at, s.req.rid))

    def _preempt(self, seq: SeqState) -> None:
        """Evict by recompute: free the blocks, keep the *committed*
        generated tokens, and requeue at the *front* (the victim predates
        everything still queued, so FIFO order is preserved).  The evicted
        ``SeqState`` is marked dead — the engine must drop its uncommitted
        in-flight tokens, which greedy decode regenerates deterministically
        after re-admission — and on re-admission the prompt plus committed
        tokens re-prefill, then decode continues."""
        if seq.blocks:
            self.pool.free(seq.blocks)
        seq.blocks = []
        seq.dead = True
        self.slots[seq.slot] = None
        self.queue.appendleft(seq.req)
        self.stats.preemptions += 1
