"""Batched serving engine: continuous-batching decode over a KV cache pool.

A minimal-but-real engine in the vLLM mold, sized for the dry-run shapes:

* requests arrive with a prompt; the engine packs up to ``max_batch`` live
  sequences into one decode batch backed by a shared cache;
* prefill runs per-request (right-padded into the batch slot), decode runs
  for the whole batch every step;
* finished sequences (EOS or ``max_new``) free their slot for the next
  queued request (continuous batching).

The compiled decode step is shape-stable: (B, 1) tokens + the cache pytree,
so serving never recompiles after warmup.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.params import MachineDescription, TPU_V5E
from ..models import init_cache
from ..models.config import ModelConfig
from .steps import build_serve_steps, greedy_sample

PyTree = Any


def warm_kernel_dispatch(cfg: ModelConfig, *,
                         machine: MachineDescription = TPU_V5E,
                         max_len: int = 512,
                         freeze: bool = True,
                         plan_store: Any = None) -> Dict[str, Any]:
    """Pre-resolve the kernel variants this model's serve path will ask for.

    Thin wrapper over :mod:`repro.plans`: the warm set is no longer a hand
    list but the config's *traced* dispatch set
    (:func:`repro.plans.trace.trace_warm_set` — so Mamba/hybrid configs warm
    ``ssd_scan``, MoE configs warm their router/expert projections, whisper
    warms the encoder shapes).  Two paths:

    - **plan-backed** (preferred): with ``freeze=True``, a serve-plan
      artifact built offline by ``scripts/plan_artifacts.py`` — looked up in
      ``plan_store`` (a :class:`repro.plans.PlanStore`), or the
      ``REPRO_ARTIFACT_DIR``-resolved store when ``plan_store`` is ``None``
      — is fed straight to :meth:`DispatchCache.freeze_resolved`.  Zero
      online tree enumeration; ``stats.cold_builds`` stays 0.  Pass
      ``plan_store=False`` to skip the artifact probe.
    - **online fallback**: trace, resolve every triple through the tiers
      (triples infeasible at this config's shapes are dropped), and — with
      ``freeze=True`` (default) — snapshot them into the process cache's
      frozen dispatch plan (:meth:`DispatchCache.freeze`): the steady-state
      read path then takes no lock, re-sorts no keys, and returns the
      pre-instantiated kernel callable.

    Returns ``{label: {"candidate": Candidate, "rank_source": str}}`` where
    ``label`` is the traced op label (``family@<sorted dims>``) and
    ``rank_source`` reports whether the pick was decided by a *measured*
    (tuned — see ``scripts/tune_artifacts.py``) ranking, the *symbolic*
    precompiled ranking, or a *cold* rebuild: the calibrated-vs-symbolic
    observability hook for serving start-up logs.  Attribution comes from
    the resolution itself (:meth:`DispatchCache.best_variant_with_source`),
    or — plan-backed — from the resolution recorded at plan-build time.
    """
    from ..artifacts.dispatch import get_default_cache
    from ..kernels.ops import FAMILIES
    from ..plans.loader import warm_from_plan
    from ..plans.trace import trace_warm_set
    cache = get_default_cache()

    if freeze and plan_store is not False:
        picks = warm_from_plan(cfg, machine=machine, max_len=max_len,
                               store=plan_store or None, cache=cache)
        if picks is not None:
            return picks

    wanted: List[Any] = []
    picks: Dict[str, Any] = {}
    for op in trace_warm_set(cfg, max_len=max_len):
        fam, data = FAMILIES[op.family], op.data_dict()
        try:
            # feasibility probe (and the full resolution when not freezing;
            # under freeze the snapshot below re-resolves via the warm LRU)
            cand, source = cache.best_variant_with_source(fam, machine, data)
        except ValueError:
            continue                        # no feasible leaf at this shape
        wanted.append((op.label, op.family, fam, data))
        if not freeze:
            picks[op.label] = {"candidate": cand, "rank_source": source}
    if freeze:
        # freeze resolves through the locked tiers (never the old frozen
        # plan), so a re-warm-up after compiling/tuning artifacts reports
        # and pins FRESH resolutions; picks come from the published plan
        plan = cache.freeze([(fam, machine, data)
                             for _, _, fam, data in wanted])
        for label, fname, _, data in wanted:
            ent = plan.get(fname, machine.name, data)
            picks[label] = {"candidate": ent.candidate,
                            "rank_source": ent.source}
    return picks


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 max_batch: int = 8, max_len: int = 512,
                 warm_kernels: bool = False,
                 plan_store: Any = None,
                 machine: MachineDescription = TPU_V5E):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # resolve kernel-variant dispatch up front: a shipped serve-plan
        # artifact when one matches (zero cold resolutions), else the traced
        # online warm-up (artifact/LRU resolution + freeze)
        self.kernel_plan = (warm_kernel_dispatch(cfg, machine=machine,
                                                 max_len=max_len,
                                                 plan_store=plan_store)
                            if warm_kernels else None)
        prefill_step, decode_step = build_serve_steps(cfg)
        # per-slot prefill: batch dim 1 keeps the compiled shape stable
        self._prefill = jax.jit(prefill_step)
        self._decode = jax.jit(decode_step, donate_argnums=(2,))
        self.cache = init_cache(cfg, max_batch, max_len)
        self.index = np.zeros(max_batch, np.int32)       # per-slot position
        self.last_tok = np.zeros((max_batch, 1), np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: collections.deque = collections.deque()
        self._rid = 0

    # -- client API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               eos: Optional[int] = None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  max_new, eos))
        return self._rid

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.slots[slot] = req
            # per-request prefill into a FRESH batch-1 cache, then scatter
            # the slot's rows into the pool.  Zeroing matters: attention KV
            # rows are position-masked, but recurrent SSM state from the
            # slot's previous occupant would contaminate the new request.
            sub = jax.tree.map(
                lambda c: jnp.zeros_like(c[:, slot:slot + 1]), self.cache)
            toks = jnp.asarray(req.prompt[None, :])
            logits, sub = self._prefill(self.params, toks, sub)
            self.cache = jax.tree.map(
                lambda pool, s: pool.at[:, slot:slot + 1].set(s),
                self.cache, sub)
            nxt = np.asarray(greedy_sample(logits))      # (1,1)
            self.index[slot] = req.prompt.shape[0]
            self.last_tok[slot] = nxt[0]
            req.out.append(int(nxt[0, 0]))

    def _retire(self) -> List[Request]:
        done = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if req.eos is not None and req.eos in req.out:
                # stop at the first EOS; later speculative tokens (decode
                # runs before retire) are truncated away
                req.out = req.out[:req.out.index(req.eos) + 1]
                req.done = True
            elif len(req.out) >= req.max_new:
                req.out = req.out[:req.max_new]
                req.done = True
            if req.done:
                done.append(req)
                self.slots[slot] = None
        return done

    def step(self) -> List[Request]:
        """One engine tick: admit, decode the live pool, retire."""
        self._admit()
        live = [s for s in range(self.max_batch) if self.slots[s] is not None]
        if live:
            # one decode for the whole pool with per-row cache indices
            # (continuous batching); dead slots write garbage at their own
            # positions, which the next admit's prefill overwrites.
            toks = jnp.asarray(self.last_tok)
            logits, self.cache = self._decode(
                self.params, toks, self.cache,
                jnp.asarray(self.index, jnp.int32))
            nxt = np.asarray(greedy_sample(logits))
            for s in live:
                self.last_tok[s] = nxt[s]
                self.index[s] += 1
                self.slots[s].out.append(int(nxt[s, 0]))
        return self._retire()

    def run_until_drained(self, max_ticks: int = 1000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            finished.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return finished
