"""Paged serving engine: refcounted block KV pool + chunked prefill +
prefix sharing + async plan/execute/commit tick overlap.

A minimal-but-real engine in the vLLM mold, sized for the dry-run shapes:

* **block/paged KV cache** — attention K/V live in a shared pool of
  fixed-size blocks (:class:`repro.runtime.kv_pool.PagedKVPool` owns the
  accounting, :func:`repro.models.init_paged_cache` the device layout).
  A request owns ``ceil(tokens / page_size)`` blocks listed in its block
  table; retirement drops refcounts copy-free.  KV memory scales with
  *live tokens*, not ``max_batch × max_len``.
* **prefix sharing (copy-on-write)** — with ``prefix_sharing=True`` the
  pool indexes full ``page_size``-aligned prompt blocks by chain hash; a
  request whose prompt shares a prefix with a live or recently-retired
  sequence *maps* the resident blocks (refcount up, prefill skipped) and
  only computes the tail.  A write into a shared block first duplicates
  it device-side (:func:`repro.models.paged_copy_block`) — the scheduler
  plans the copy, :meth:`ServeEngine._dispatch` executes it before the
  tick's prefill/decode.  Recurrent SSM state cannot skip prompt tokens,
  so sharing is forced off for SSM-bearing configs (``ssm``/``hybrid``).
* **chunked prefill** — prompts enter the cache one scheduler-visible
  chunk per tick, interleaved with decode, so a long prompt never stalls
  in-flight decodes for its whole length.  Chunk lengths are quantized
  (``prefill_chunk``-sized chunks + a power-of-two tail) so the compiled
  prefill-shape set is O(log ``prefill_chunk``), with no padding — the
  recurrent SSM state threads exactly and chunked prefill is token-for-
  token equal to whole-prompt prefill.
* **async tick overlap** — each engine step is **plan → dispatch →
  commit**.  Dispatch enqueues the tick's jit'd closures and keeps the
  sampled tokens *on device* (``last_tok`` chains device-resident into
  the next dispatch), so with ``async_depth=2`` the host plans and
  dispatches tick *t+1* while the device still executes tick *t*; the
  only host synchronization is the commit barrier, which materializes a
  finished tick's sampled tokens, appends them to request outputs, and
  reconciles EOS/``max_new`` truncation *before the next dispatch*.
  ``async_depth=1`` commits each tick immediately after dispatch — the
  fully synchronous engine.  Speculation is bounded host-side: the
  scheduler's dispatch guard never sends a sequence past its ``max_new``
  budget, preempted sequences are marked dead so their uncommitted
  in-flight tokens are dropped (greedy recompute regenerates them
  deterministically), and tokens past an EOS are truncated at commit.

The compiled steps are shape-stable — decode is (B, 1) tokens + (B, nblk)
block tables every tick; prefill compiles one variant per quantized chunk
length; the CoW block copy is one scalar-indexed kernel — so serving
never recompiles after warmup.

With ``monitor=True`` (and warmed kernels) the engine additionally runs
the **adaptive loop** (:mod:`repro.runtime.monitor`): cheap wall-clock
probes over the frozen kernel picks during live traffic, and an atomic
hot-swap of any pick that measurement persistently contradicts —
KLARAPTOR's runtime selection grafted onto the offline plan.  Plan-backed
starts also digest-check their serve plan against the host's dispatch
tables (``strict_plans`` escalates the staleness warning to a refusal).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.params import MachineDescription, TPU_V5E
from ..models import (init_paged_cache, paged_copy_block, paged_decode_step,
                      paged_prefill_chunk)
from ..models.config import ModelConfig
from ..obs import ObsRegistry
from ..obs import recorder as obs
from ..obs.events import TickSpan
from . import faults
from .faults import TickWatchdog
from .kv_pool import GARBAGE_BLOCK, PagedKVPool
from .monitor import KernelMonitor
from .scheduler import Request, Scheduler, SeqState, TickPlan
from .steps import greedy_sample

PyTree = Any


def warm_kernel_dispatch(cfg: ModelConfig, *,
                         machine: MachineDescription = TPU_V5E,
                         max_len: int = 512,
                         page_size: int = 0,
                         freeze: bool = True,
                         plan_store: Any = None,
                         strict_plans: bool = False) -> Dict[str, Any]:
    """Pre-resolve the kernel variants this model's serve path will ask for.

    Thin wrapper over :mod:`repro.plans`: the warm set is no longer a hand
    list but the config's *traced* dispatch set
    (:func:`repro.plans.trace.trace_warm_set` — so Mamba/hybrid configs warm
    ``ssd_scan``, MoE configs warm their router/expert projections, whisper
    warms the encoder shapes).  ``page_size > 0`` traces the *paged* serve
    path: attention sequence extents round up to the block grid, so the
    dispatch bucket keys carry the block size and a paged engine start hits
    the same frozen entries it will dispatch through (``page_size=0`` keeps
    the dense trace).  Two paths:

    - **plan-backed** (preferred): with ``freeze=True``, a serve-plan
      artifact built offline by ``scripts/plan_artifacts.py`` — looked up in
      ``plan_store`` (a :class:`repro.plans.PlanStore`), or the
      ``REPRO_ARTIFACT_DIR``-resolved store when ``plan_store`` is ``None``
      — is fed straight to :meth:`DispatchCache.freeze_resolved`.  Zero
      online tree enumeration; ``stats.cold_builds`` stays 0.  Pass
      ``plan_store=False`` to skip the artifact probe.  A plan whose
      recorded dispatch-table digests no longer match this host's tables
      is *stale*: by default it warns (``StalePlanWarning``) and falls
      through to online warm-up; ``strict_plans=True`` raises
      :class:`repro.plans.StalePlanError` instead (the ``--strict-plans``
      refusal).
    - **online fallback**: trace, resolve every triple through the tiers
      (triples infeasible at this config's shapes are dropped), and — with
      ``freeze=True`` (default) — snapshot them into the process cache's
      frozen dispatch plan (:meth:`DispatchCache.freeze`): the steady-state
      read path then takes no lock, re-sorts no keys, and returns the
      pre-instantiated kernel callable.

    Returns ``{label: {"candidate": Candidate, "rank_source": str}}`` where
    ``label`` is the traced op label (``family@<sorted dims>``) and
    ``rank_source`` reports whether the pick was decided by a *measured*
    (tuned — see ``scripts/tune_artifacts.py``) ranking, the *symbolic*
    precompiled ranking, or a *cold* rebuild: the calibrated-vs-symbolic
    observability hook for serving start-up logs.  Attribution comes from
    the resolution itself (:meth:`DispatchCache.best_variant_with_source`),
    or — plan-backed — from the resolution recorded at plan-build time.
    """
    from ..artifacts.dispatch import get_default_cache
    from ..kernels.ops import FAMILIES
    from ..plans.loader import warm_from_plan
    from ..plans.trace import trace_warm_set
    cache = get_default_cache()

    if freeze and plan_store is not False:
        picks = warm_from_plan(cfg, machine=machine, max_len=max_len,
                               page_size=page_size,
                               store=plan_store or None, cache=cache,
                               strict=strict_plans)
        if picks is not None:
            return picks

    wanted: List[Any] = []
    picks: Dict[str, Any] = {}
    for op in trace_warm_set(cfg, max_len=max_len, page_size=page_size):
        fam, data = FAMILIES[op.family], op.data_dict()
        try:
            # feasibility probe (and the full resolution when not freezing;
            # under freeze the snapshot below re-resolves via the warm LRU)
            cand, source = cache.best_variant_with_source(fam, machine, data)
        except ValueError:
            continue                        # no feasible leaf at this shape
        wanted.append((op.label, op.family, fam, data))
        if not freeze:
            picks[op.label] = {"candidate": cand, "rank_source": source}
    if freeze:
        # freeze resolves through the locked tiers (never the old frozen
        # plan), so a re-warm-up after compiling/tuning artifacts reports
        # and pins FRESH resolutions; picks come from the published plan
        plan = cache.freeze([(fam, machine, data)
                             for _, _, fam, data in wanted])
        for label, fname, _, data in wanted:
            ent = plan.get(fname, machine.name, data)
            picks[label] = {"candidate": ent.candidate,
                            "rank_source": ent.source}
    return picks


@dataclass
class _InFlight:
    """One dispatched-but-uncommitted tick: the device handles of its
    sampled tokens plus the sequences they belong to.  Committing it is
    the pipeline's only host sync."""

    prefill_seed: Optional[Tuple[SeqState, jax.Array]] = None  # (seq, (1,1))
    decode_toks: Optional[jax.Array] = None                    # (B, 1)
    decode_seqs: List[SeqState] = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 max_batch: int = 8, max_len: int = 512,
                 page_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefill_chunk: int = 32,
                 watermark_blocks: Optional[int] = None,
                 prefix_sharing: bool = False,
                 async_depth: int = 1,
                 warm_kernels: bool = False,
                 plan_store: Any = None,
                 strict_plans: bool = False,
                 monitor: bool = False,
                 monitor_window: int = 8,
                 monitor_every: int = 4,
                 swap_threshold: float = 1.25,
                 swap_patience: int = 2,
                 monitor_timer: Any = None,
                 degrade: bool = False,
                 max_queue: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 watchdog: bool = True,
                 clock: faults.Clock = faults.default_clock,
                 machine: MachineDescription = TPU_V5E):
        if cfg.encoder is not None:
            raise ValueError("ServeEngine does not serve encoder-decoder "
                             "configs")
        if async_depth < 1:
            raise ValueError(f"async_depth must be >= 1: {async_depth}")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.page_size = page_size
        self.async_depth = async_depth
        self.machine = machine
        # graceful degradation (repro.runtime.faults + DispatchCache.demote):
        # a recoverable failure inside a guarded tick stage demotes a frozen
        # kernel pick and retries once; a second failure poisons the affected
        # sequences (preempt-by-recompute) instead of killing the engine.
        # Off by default: masking a genuine bug behind a silent retry is the
        # wrong default for development; serving deployments opt in.
        self.degrade = degrade
        self.deadline_ms = deadline_ms
        self.clock = clock
        self.watchdog: Optional[TickWatchdog] = (TickWatchdog() if watchdog
                                                 else None)
        # prompt-skipping needs every skipped position recoverable from the
        # KV pool alone; SSM recurrent state must thread through *every*
        # prompt token, so SSM-bearing configs always prefill in full
        self.prefix_sharing = prefix_sharing and cfg.block not in (
            "ssm", "hybrid")
        self.blocks_per_seq = -(-max_len // page_size)
        if num_blocks is None:
            # default pool: every slot can hold a full-length sequence
            # (+ the reserved garbage block), so admission is slot-bound
            # exactly like the dense engine was.  Size it smaller to
            # exercise head-room waits and preemption.
            num_blocks = max_batch * self.blocks_per_seq + 1
        # resolve kernel-variant dispatch up front: a shipped serve-plan
        # artifact when one matches (zero cold resolutions), else the traced
        # online warm-up (artifact/LRU resolution + freeze).  The paged
        # block size is part of the traced bucket keys.
        self.kernel_plan = (warm_kernel_dispatch(cfg, machine=machine,
                                                 max_len=max_len,
                                                 page_size=page_size,
                                                 plan_store=plan_store,
                                                 strict_plans=strict_plans)
                            if warm_kernels else None)
        # adaptive loop (repro.runtime.monitor): live counters over the
        # frozen picks + hot-swap when measurement disagrees.  Off by
        # default — probing runs real kernels; enable it with an injected
        # timer (tests/benchmarks) or on hosts where probe cost is cheap.
        self.monitor: Optional[KernelMonitor] = None
        if monitor and self.kernel_plan is not None:
            self.monitor = KernelMonitor(
                machine=machine, window=monitor_window,
                probe_every=monitor_every, threshold=swap_threshold,
                patience=swap_patience, timer=monitor_timer)
            self.monitor.track_frozen()
        self.pool = PagedKVPool(num_blocks, page_size)
        self.sched = Scheduler(self.pool, max_batch=max_batch,
                               max_len=max_len, prefill_chunk=prefill_chunk,
                               watermark_blocks=watermark_blocks,
                               prefix_sharing=self.prefix_sharing,
                               max_queue=max_queue, clock=clock)
        # the cache this engine demotes through — captured at construction
        # so benches/tests that install a private default cache get their
        # degrade events in that cache, not a later global
        from ..artifacts.dispatch import get_default_cache
        self._cache = get_default_cache()
        self._degrade_rr = 0                 # round-robin over frozen triples
        self._rejected: List[Request] = []   # shed at submit, surfaced by step

        def _prefill(params, tokens, cache, start, block_table, slot):
            logits, cache = paged_prefill_chunk(params, cfg, tokens, cache,
                                                start, block_table, slot)
            # sample in-jit: the seed token stays device-resident until the
            # commit barrier materializes it
            return greedy_sample(logits), cache

        def _decode(params, last_tok, cache, index, block_tables, mask):
            logits, cache = paged_decode_step(params, cfg, last_tok, cache,
                                              index, block_tables,
                                              ssm_mask=mask)
            nxt = greedy_sample(logits)
            # chain last_tok device-side: decoding rows advance to their
            # sampled token, everything else (dead rows, mid-prefill rows)
            # keeps its value — no host sync between ticks
            return nxt, jnp.where(mask[:, None], nxt, last_tok), cache

        # one compile per quantized chunk length; decode + CoW copy are
        # shape-stable
        self._prefill = jax.jit(_prefill, donate_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._copy = jax.jit(paged_copy_block, donate_argnums=(0,))
        self.cache = init_paged_cache(cfg, num_blocks, page_size, max_batch)
        self.last_tok = jnp.zeros((max_batch, 1), jnp.int32)
        self._inflight: Deque[_InFlight] = collections.deque()
        self._rid = 0

    # -- client API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               eos: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> int:
        """Queue a request; returns its rid.

        Malformed input (empty prompt, ``max_new < 1``, prompt + budget
        over ``max_len``/pool capacity) raises a structured
        :class:`~repro.runtime.scheduler.RequestError` — a ``ValueError``
        subclass, so pre-existing callers keep working.  A well-formed
        request shed by the queue bound (``max_queue``) does NOT raise: it
        comes back *done* from a later :meth:`step` with ``req.error.code
        == "queue_full"`` and a retry-after hint.  ``deadline_ms``
        overrides the engine-level TTL for this request (absolute deadline
        = now + TTL on the engine's clock)."""
        self._rid += 1
        ms = deadline_ms if deadline_ms is not None else self.deadline_ms
        deadline = (self.clock() + ms / 1000.0) if ms is not None else None
        req = Request(self._rid, np.asarray(prompt, np.int32), max_new, eos,
                      deadline=deadline)
        if self.sched.submit(req) is not None:
            self._rejected.append(req)       # shed: surfaced as done
        return self._rid

    # -- tick execution -------------------------------------------------------
    def _block_table(self, seq: SeqState) -> np.ndarray:
        """Fixed-width (nblk,) table: owned blocks in logical order, tail
        padded with the garbage block (never addressed: positions beyond
        the sequence are causally masked)."""
        bt = np.full(self.blocks_per_seq, GARBAGE_BLOCK, np.int32)
        bt[:len(seq.blocks)] = seq.blocks
        return bt

    def _reset_slot(self, slot: int) -> None:
        # KV needs no wipe — stale blocks are position-masked until their
        # next owner overwrites them — but the recurrent SSM state is
        # per-slot and must start from zero for a new occupant.
        self.last_tok = self.last_tok.at[slot].set(0)
        if "ssm" in self.cache:
            self.cache["ssm"] = self.cache["ssm"].at[:, slot].set(0.0)

    def step(self) -> List[Request]:
        """One engine tick: plan + dispatch the next tick, then commit the
        oldest in-flight tick(s) down to the pipeline depth.  At
        ``async_depth=1`` the dispatched tick commits immediately
        (synchronous engine); at depth ``d`` the newest ``d − 1`` ticks
        stay in flight across the return, overlapping host planning with
        device execution."""
        faults.set_tick(self.sched.ticks)    # arm the drill's tick cursor
        obs.set_tick(self.sched.ticks)       # ...and the trace's, in lockstep
        orec = obs.get_recorder()
        timed = self.watchdog is not None or orec is not None
        t0 = self.clock() if timed else 0.0
        done: List[Request] = []
        if self._rejected:                   # shed submits surface as done
            done.extend(self._rejected)
            self._rejected.clear()
        if self.monitor is not None:
            # adaptive loop: cheap counter sampling + (rarely) a hot-swap
            # through the cache's atomic publish; one modulo check on
            # non-probe ticks
            self.monitor.on_tick(self.sched.ticks)
        tick = self.sched.ticks
        plan = self.sched.tick()
        done.extend(plan.cancelled)          # deadline-expired: partial out
        self._dispatch(plan)
        while len(self._inflight) > self.async_depth - 1:
            done.extend(self._commit(self._inflight.popleft()))
        if timed:
            dt = self.clock() - t0
            spec = faults.maybe_fault("serve.tick")
            if spec is not None and spec.kind == "slow":
                dt += spec.arg / 1e6         # injected hang, in microseconds
            if self.watchdog is not None:
                self.watchdog.observe(dt, tick)
            if orec is not None:
                # one span per tick: what the plan scheduled, what
                # committed, and the host-side duration on the engine's
                # injectable clock (tick indices are the only timestamps,
                # so a counting clock makes the whole trace deterministic)
                orec.emit(TickSpan(
                    tick=tick, admitted=len(plan.admitted),
                    prefill_tokens=(plan.prefill[2]
                                    if plan.prefill is not None else 0),
                    decode_rows=len(plan.decode),
                    preempted=len(plan.preempted),
                    cancelled=len(plan.cancelled), finished=len(done),
                    duration_us=dt * 1e6))
        return done

    def _guard(self, site: str, seqs: Tuple[SeqState, ...], fn, *args):
        """Run one guarded tick stage: consult the fault injector, then the
        stage itself.  A recoverable failure with ``degrade`` on demotes
        the next frozen kernel pick (round-robin over the frozen triples —
        the engine cannot attribute a batched-step failure to one kernel,
        so successive failures walk the whole warm set down their
        rankings) and retries the stage once; a second failure **poisons**
        ``seqs`` — preempt-by-recompute, reconciled at the commit barrier
        — and returns ``None`` (the stage's work is skipped this tick).
        With ``degrade`` off, or on a :class:`~repro.runtime.faults.
        FatalFault`, the exception propagates — the caller's partial-tick
        bookkeeping keeps the engine drainable."""
        try:
            faults.maybe_fault(site)
            return fn(*args)
        except faults.FatalFault:
            raise
        except Exception as e:               # noqa: BLE001 — degrade surface
            if not self.degrade:
                raise
            self._demote_next(e)
            try:
                faults.maybe_fault(site)
                return fn(*args)
            except faults.FatalFault:
                raise
            except Exception:                # noqa: BLE001 — second strike
                for seq in seqs:
                    self.sched.poison(seq)
                return None

    def _demote_next(self, error: Exception) -> None:
        """Fall one frozen pick down its ranking (no-op without a frozen
        plan: there is no pinned pick to blame, and the locked tiers
        already re-resolve per call)."""
        plan = self._cache.frozen_plan
        triples = [t for t in (plan.triples if plan is not None else ())
                   if t[1].name == self.machine.name]
        if not triples:
            return
        fam, mach, data = triples[self._degrade_rr % len(triples)]
        self._degrade_rr += 1
        self._cache.demote(fam, mach, data, error=error,
                           tick=self.sched.ticks)

    def _dispatch(self, plan: TickPlan) -> None:
        """Execute one tick plan: enqueue the CoW copies, at most one
        prefill chunk, and the batched decode; record the device handles
        of the sampled tokens as an in-flight tick.  No host sync here —
        position accounting advances speculatively (note_prefill /
        note_decode), outputs land at commit.

        Every device stage runs under :meth:`_guard`; a stage that fails
        twice poisons its sequences and is skipped (a poisoned sequence is
        dead — later stages this tick must not touch it, hence the
        ``dead`` re-checks).  The in-flight record is appended even when a
        fatal fault aborts the tick midway: whatever was dispatched before
        the abort must still reach the commit barrier, or the pipeline's
        position accounting wedges and the engine can never drain."""
        for seq in plan.admitted:
            self._reset_slot(seq.slot)
        rec = _InFlight()
        try:
            for (src, dst), owner in zip(plan.cow, plan.cow_owners):
                # duplicate shared blocks BEFORE this tick writes into
                # them; other owners keep reading the original
                out = self._guard("serve.cow", (owner,), self._copy,
                                  self.cache, jnp.int32(src), jnp.int32(dst))
                if out is not None:
                    self.cache = out
            if plan.prefill is not None and not plan.prefill[0].dead:
                seq, start, chunk = plan.prefill
                toks = jnp.asarray(seq.target[None, start:start + chunk])
                out = self._guard("serve.prefill", (seq,), self._prefill,
                                  self.params, toks, self.cache,
                                  jnp.int32(start),
                                  jnp.asarray(self._block_table(seq)[None]),
                                  jnp.int32(seq.slot))
                if out is not None:
                    seed, self.cache = out
                    self.sched.note_prefill(seq, chunk)
                    if not seq.prefilling:
                        # final chunk: its last-token logits seed decode,
                        # exactly as whole-prompt prefill would
                        self.last_tok = self.last_tok.at[seq.slot].set(seed[0])
                        rec.prefill_seed = (seq, seed)
            decoding = [s for s in plan.decode if not s.dead]
            if decoding:
                bts = np.full((self.max_batch, self.blocks_per_seq),
                              GARBAGE_BLOCK, np.int32)
                idx = np.zeros(self.max_batch, np.int32)
                mask = np.zeros(self.max_batch, bool)
                for seq in decoding:
                    bts[seq.slot, :len(seq.blocks)] = seq.blocks
                    idx[seq.slot] = seq.pos
                    mask[seq.slot] = True
                # one decode for the whole pool with per-row block tables
                # (continuous batching); non-decoding rows write the garbage
                # block and keep their SSM state via the mask.
                out = self._guard("serve.decode", tuple(decoding),
                                  self._decode, self.params, self.last_tok,
                                  self.cache, jnp.asarray(idx),
                                  jnp.asarray(bts), jnp.asarray(mask))
                if out is not None:
                    toks, self.last_tok, self.cache = out
                    for seq in decoding:
                        self.sched.note_decode(seq)
                    rec.decode_toks = toks
                    rec.decode_seqs = list(decoding)
        except BaseException:
            # partial tick (degrade off or fatal): keep what was dispatched
            # committable, then fail loudly — run_until_drained still works
            self._inflight.append(rec)
            raise
        self._inflight.append(rec)

    def _commit(self, rec: _InFlight) -> List[Request]:
        """Commit barrier: materialize one finished tick's sampled tokens
        (the pipeline's only host sync), append them to request outputs —
        skipping sequences preempted (dead: greedy recompute regenerates
        their tokens) or already finished (EOS found by an earlier commit:
        later speculative tokens are discarded) — then reconcile EOS /
        ``max_new`` and retire."""
        if rec.prefill_seed is not None:
            seq, seed = rec.prefill_seed
            if not seq.dead and not seq.req.done:
                seq.req.out.append(int(np.asarray(seed)[0, 0]))
        if rec.decode_seqs:
            nxt = np.asarray(rec.decode_toks)
            for seq in rec.decode_seqs:
                if seq.dead or seq.req.done:
                    continue
                seq.req.out.append(int(nxt[seq.slot, 0]))
        return self._retire()

    def _retire(self) -> List[Request]:
        done = []
        for seq in list(self.sched.running()):
            if seq.prefilling:
                continue
            req = seq.req
            if req.eos is not None and req.eos in req.out:
                # stop at the first EOS; later speculative tokens are
                # truncated away
                req.out = req.out[:req.out.index(req.eos) + 1]
                req.done = True
            elif len(req.out) >= req.max_new:
                req.out = req.out[:req.max_new]
                req.done = True
            if req.done:
                done.append(req)
                self.sched.retire(seq)       # copy-free: refcounts drop
        return done

    # -- observability --------------------------------------------------------
    def registry(self) -> ObsRegistry:
        """This engine's unified metrics registry: pool, scheduler,
        dispatch cache, monitor, and watchdog behind one ``snapshot()`` /
        ``render_text()`` / ``summary_line()`` surface.  Parts are
        resolved per snapshot, so a monitor attached later is reported."""
        return ObsRegistry.from_engine(self)

    @property
    def degrade_events(self):
        """The dispatch cache's recorded :class:`~repro.artifacts.dispatch.
        DegradeEvent`s (this engine demotes through its captured cache)."""
        return self._cache.degrade_events

    def robustness_line(self) -> str:
        s = self.sched.stats
        line = (f"robustness shed={s.shed} cancelled={s.cancelled} "
                f"poisoned={s.poisoned} "
                f"demotions={self._cache.stats.demotions}")
        if self.watchdog is not None:
            line += " | " + self.watchdog.stats_line()
        return line

    def run_until_drained(self, max_ticks: int = 1000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            finished.extend(self.step())
            if not self.sched.has_work():
                break
        # drain the pipeline: ticks still in flight when the queue empties
        # (async_depth > 1) carry the final tokens of the last requests
        while self._inflight:
            finished.extend(self._commit(self._inflight.popleft()))
        return finished
