"""Runtime: step builders, fault tolerance, serving engine."""
from .steps import (build_eval_step, build_serve_steps, build_train_step,
                    cross_entropy, greedy_sample, loss_fn)
from .ft import StragglerMonitor, TrainController, elastic_mesh_shape
from .serving import Request, ServeEngine

__all__ = ["build_eval_step", "build_serve_steps", "build_train_step",
           "cross_entropy", "greedy_sample", "loss_fn", "StragglerMonitor",
           "TrainController", "elastic_mesh_shape", "Request", "ServeEngine"]
