"""Runtime: step builders, fault tolerance, paged serving engine,
adaptive kernel monitoring, chaos-injection drills.

Exports resolve lazily (PEP 562): :mod:`repro.artifacts.store` imports
:mod:`repro.runtime.faults` at module scope, and an eager ``from .steps
import ...`` here would pull jax into every artifact read.  Attribute
access triggers the real import, so ``from repro.runtime import
ServeEngine`` still works unchanged.
"""
from typing import Dict

_EXPORTS: Dict[str, str] = {
    # steps
    "build_eval_step": "steps", "build_serve_steps": "steps",
    "build_train_step": "steps", "cross_entropy": "steps",
    "greedy_sample": "steps", "loss_fn": "steps",
    # ft
    "StragglerMonitor": "ft", "TrainController": "ft",
    "elastic_mesh_shape": "ft",
    # faults
    "ANY_TICK": "faults", "FaultError": "faults", "FaultInjector": "faults",
    "FaultSchedule": "faults", "FaultSpec": "faults", "FatalFault": "faults",
    "InjectedFault": "faults", "InjectedIOFault": "faults",
    "TickWatchdog": "faults", "inject": "faults",
    # kv_pool
    "GARBAGE_BLOCK": "kv_pool", "PREFIX_ROOT": "kv_pool",
    "PagedKVPool": "kv_pool", "PoolStats": "kv_pool",
    # monitor
    "KernelMonitor": "monitor", "MonitorStats": "monitor",
    "SwapEvent": "monitor", "cand_key": "monitor",
    # scheduler
    "Request": "scheduler", "RequestError": "scheduler",
    "Scheduler": "scheduler", "SeqState": "scheduler",
    "TickPlan": "scheduler",
    # serving
    "ServeEngine": "serving", "warm_kernel_dispatch": "serving",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(f".{module}", __name__), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
