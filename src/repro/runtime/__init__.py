"""Runtime: step builders, fault tolerance, paged serving engine,
adaptive kernel monitoring."""
from .steps import (build_eval_step, build_serve_steps, build_train_step,
                    cross_entropy, greedy_sample, loss_fn)
from .ft import StragglerMonitor, TrainController, elastic_mesh_shape
from .kv_pool import GARBAGE_BLOCK, PREFIX_ROOT, PagedKVPool, PoolStats
from .monitor import KernelMonitor, MonitorStats, SwapEvent, cand_key
from .scheduler import Request, Scheduler, SeqState, TickPlan
from .serving import ServeEngine, warm_kernel_dispatch

__all__ = ["build_eval_step", "build_serve_steps", "build_train_step",
           "cross_entropy", "greedy_sample", "loss_fn", "StragglerMonitor",
           "TrainController", "elastic_mesh_shape", "GARBAGE_BLOCK",
           "PREFIX_ROOT", "PagedKVPool", "PoolStats", "KernelMonitor",
           "MonitorStats", "SwapEvent", "cand_key", "Request", "Scheduler",
           "SeqState", "TickPlan", "ServeEngine", "warm_kernel_dispatch"]
