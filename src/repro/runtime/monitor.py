"""Live kernel monitoring + counter-driven hot-swap (KLARAPTOR at serve
time).

Serve plans freeze kernel picks *offline*; KLARAPTOR (PAPERS.md, arxiv
1911.02373) argues launch parameters are best revisited *at program
runtime*, when measured reality can disagree with the offline model —
traffic mix shifts, a mis-calibrated tuning run, a table built on a
different host.  :class:`KernelMonitor` closes that loop for the frozen
fast lane:

* **probe** — every ``probe_every``-th engine tick, one tracked
  ``(family, machine, data)`` triple (round-robin) gets a cheap wall-clock
  probe: the frozen incumbent plus one pre-ranked challenger are timed via
  an injectable :data:`repro.tuning.measure.Timer` (the tests and
  benchmarks supply deterministic fakes; a TPU host supplies a hardware
  timer).  Samples land in fixed-size reservoirs — bounded memory, seeded
  RNG, no unbounded history.  Non-probe ticks cost one modulo check, so
  the frozen fast path stays effectively free.
* **decide** — after ``window`` probes of a triple the window closes: if
  the best challenger's median beats the incumbent's median by more than
  ``threshold`` (a ratio, e.g. ``1.25`` = 25% faster) the window
  *disagrees* with the frozen pick.  ``patience`` consecutive disagreeing
  windows — one noisy window never swaps — trigger a hot-swap.
* **swap** — the challenger is first re-proven feasible against the
  comprehensive tree's constraint system (measured speed never overrides
  the constraint model: an infeasible candidate is dropped from the
  challenger pool and counted, never published).  The corrected pick is
  then published through the existing atomic
  :meth:`DispatchCache.freeze_resolved` merge, guarded by the cache's
  unfreeze generation — a concurrent ``unfreeze``/``clear`` wins and the
  swap is counted as blocked, exactly the ``attach_store`` re-freeze
  discipline.  Every swap is recorded as a :class:`SwapEvent` and logged.

Counters (:class:`MonitorStats`) follow the ``PoolStats`` idiom: plain
monotonic ints, cheap to read, surfaced on the serve stats line.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..artifacts.dispatch import CandKey, cand_key  # noqa: F401 — re-export
from ..core.comprehensive import comprehensive_tree
from ..obs import recorder as obs
from ..obs.events import describe_transition
from ..core.constraints import Verdict
from ..core.params import MachineDescription, TPU_V5E
from ..core.plan import FamilySpec
from ..core.select import Candidate, rank_candidates
from ..tuning.measure import (MeasureConfig, Timer, default_timer,
                              measure_shape, trimmed_mean_us)
from . import faults

_LOG = logging.getLogger(__name__)


@dataclass
class MonitorStats:
    """Monotonic counters for the adaptive loop (PoolStats-style)."""

    probes: int = 0                   # incumbent+challenger probe pairs run
    samples: int = 0                  # reservoir samples recorded
    probe_failures: int = 0           # timer raised; failure is data
    windows: int = 0                  # decision windows closed
    disagreements: int = 0            # windows where measurement disagreed
    swaps: int = 0                    # hot-swaps published
    swap_blocked_infeasible: int = 0  # challenger failed constraint re-proof
    swap_blocked_gen: int = 0         # publish lost to concurrent unfreeze


@dataclass(frozen=True)
class SwapEvent:
    """One observable hot-swap: what was believed, what was measured."""

    tick: int
    family: str
    data: Tuple[Tuple[str, int], ...]        # sorted items
    old: CandKey
    new: CandKey
    incumbent_us: float
    challenger_us: float
    windows: int                             # disagreeing streak length

    def describe(self) -> str:
        # rendered through the shared obs convention so the swap and
        # degrade logs cannot drift (a test pins this format)
        return describe_transition(
            tick=self.tick, verb="swapped", family=self.family,
            data=self.data,
            old=f"{self.old[1]} ({self.incumbent_us:.1f}us)",
            new=f"{self.new[1]} ({self.challenger_us:.1f}us)",
            cause=f"{self.windows} windows")


class _Reservoir:
    """Fixed-size uniform sample of a candidate's probe timings."""

    __slots__ = ("cap", "seen", "xs")

    def __init__(self, cap: int):
        self.cap = max(1, int(cap))
        self.seen = 0
        self.xs: List[float] = []

    def add(self, us: float, rng: np.random.Generator) -> None:
        self.seen += 1
        if len(self.xs) < self.cap:
            self.xs.append(float(us))
        else:                                 # classic reservoir replacement
            j = int(rng.integers(0, self.seen))
            if j < self.cap:
                self.xs[j] = float(us)

    def median(self) -> Optional[float]:
        return float(np.median(self.xs)) if self.xs else None


@dataclass
class _TripleState:
    """Per tracked (family, data) bookkeeping."""

    family: FamilySpec
    data: Dict[str, int]
    pool: Optional[List[Candidate]] = None   # ranked candidate pool (lazy)
    reservoirs: Dict[CandKey, _Reservoir] = field(default_factory=dict)
    probes_in_window: int = 0
    streak: int = 0                          # consecutive disagreeing windows
    rr: int = 0                              # challenger round-robin cursor


class KernelMonitor:
    """Counter-driven re-tuning over a cache's frozen dispatch plan.

    Drive it with :meth:`on_tick` from the engine loop (or any tick
    source).  ``timer`` defaults to the real kernel timer
    (:func:`repro.tuning.measure.default_timer`) under a deliberately cheap
    :class:`MeasureConfig`; inject a fake for tests/benchmarks or a
    hardware timer on a TPU host.
    """

    def __init__(self, cache=None, *,
                 machine: MachineDescription = TPU_V5E,
                 window: int = 8, patience: int = 2,
                 threshold: float = 1.25, probe_every: int = 4,
                 top_k: int = 2, reservoir: int = 32,
                 timer: Optional[Timer] = None,
                 measure: Optional[MeasureConfig] = None,
                 ranker=None,
                 seed: int = 0):
        if window < 1:
            raise ValueError(f"window must be >= 1: {window}")
        if patience < 1:
            raise ValueError(f"patience must be >= 1: {patience}")
        if threshold < 1.0:
            raise ValueError(f"threshold must be >= 1.0: {threshold}")
        if probe_every < 1:
            raise ValueError(f"probe_every must be >= 1: {probe_every}")
        from ..artifacts.dispatch import get_default_cache
        self.cache = cache if cache is not None else get_default_cache()
        self.machine = machine
        self.window = int(window)
        self.patience = int(patience)
        self.threshold = float(threshold)
        self.probe_every = int(probe_every)
        self.top_k = int(top_k)
        self.reservoir_cap = int(reservoir)
        self.timer = timer if timer is not None else default_timer
        #: challenger source: (family, machine, data) -> ranked Candidates.
        #: Injectable so the property tests can nominate adversarial
        #: candidates; the feasibility re-proof in :meth:`_swap` holds
        #: regardless of what the ranker proposes.
        self.ranker = ranker if ranker is not None else rank_candidates
        self.measure = measure if measure is not None else MeasureConfig(
            iters=1, warmup=0, trim=0, max_dim=64, seed=seed)
        self.stats = MonitorStats()
        self.events: List[SwapEvent] = []
        self._rng = np.random.default_rng(seed)
        self._triples: Dict[Tuple[str, Tuple[Tuple[str, int], ...]],
                            _TripleState] = {}
        self._rr = 0

    # -- registration ---------------------------------------------------------
    def track(self, family: FamilySpec, data: Mapping[str, int]) -> None:
        """Track one (family, data) triple on this monitor's machine."""
        d = {k: int(v) for k, v in data.items()}
        key = (family.name, tuple(sorted(d.items())))
        self._triples.setdefault(key, _TripleState(family=family, data=d))

    def track_frozen(self, families: Optional[Sequence[str]] = None) -> int:
        """Track every triple in the cache's frozen plan (optionally
        filtered to the named families); returns how many are tracked.
        Benchmarks pass a single-family filter so detection latency is
        deterministic."""
        plan = self.cache.frozen_plan
        if plan is None:
            return 0
        allowed = set(families) if families is not None else None
        for family, machine, data in plan.triples:
            if machine.name != self.machine.name:
                continue
            if allowed is not None and family.name not in allowed:
                continue
            self.track(family, data)
        return len(self._triples)

    # -- the tick hook --------------------------------------------------------
    def on_tick(self, tick: int) -> None:
        """Called once per engine tick; probes on every ``probe_every``-th
        tick, round-robin across tracked triples.  Non-probe ticks return
        after one modulo check."""
        if not self._triples or tick % self.probe_every != 0:
            return
        states = list(self._triples.values())
        st = states[self._rr % len(states)]
        self._rr += 1
        self._probe(st, tick)

    # -- probing --------------------------------------------------------------
    def _incumbent(self, st: _TripleState) -> Optional[Candidate]:
        ent = self.cache.frozen_entry(st.family.name, self.machine.name,
                                      st.data)
        return ent.candidate if ent is not None else None

    def _pool(self, st: _TripleState) -> List[Candidate]:
        """Lazy ranked candidate pool (incumbent's rivals come from here)."""
        if st.pool is None:
            try:
                ranked = self.ranker(st.family, self.machine, st.data)
            except ValueError:
                ranked = []
            st.pool = list(ranked)[:self.top_k + 1]
        return st.pool

    def _sample(self, st: _TripleState, cand: Candidate,
                shape: Mapping[str, int]) -> None:
        try:
            faults.maybe_fault("monitor.probe")
            reps = self.timer(st.family, cand.plan, dict(cand.assignment),
                              dict(shape), self.measure)
            us = trimmed_mean_us(reps, self.measure.trim)
        except faults.FatalFault:
            raise
        except Exception:                     # noqa: BLE001 — failure is data
            self.stats.probe_failures += 1
            return
        key = cand_key(cand)
        res = st.reservoirs.get(key)
        if res is None:
            res = st.reservoirs[key] = _Reservoir(self.reservoir_cap)
        res.add(us, self._rng)
        self.stats.samples += 1

    def _probe(self, st: _TripleState, tick: int) -> None:
        incumbent = self._incumbent(st)
        if incumbent is None:
            return                            # not frozen: nothing to guard
        inc_key = cand_key(incumbent)
        rivals = [c for c in self._pool(st) if cand_key(c) != inc_key]
        if not rivals:
            return                            # nothing ranked to challenge
        challenger = rivals[st.rr % len(rivals)]
        st.rr += 1
        shape = measure_shape(
            st.family.name, st.data,
            [incumbent.assignment] + [c.assignment for c in rivals],
            self.measure.max_dim)
        self._sample(st, incumbent, shape)
        self._sample(st, challenger, shape)
        self.stats.probes += 1
        st.probes_in_window += 1
        if st.probes_in_window >= self.window:
            st.probes_in_window = 0
            self._close_window(st, tick, incumbent, rivals)

    # -- deciding -------------------------------------------------------------
    def _close_window(self, st: _TripleState, tick: int,
                      incumbent: Candidate, rivals: List[Candidate]) -> None:
        self.stats.windows += 1
        inc_res = st.reservoirs.get(cand_key(incumbent))
        inc_med = inc_res.median() if inc_res is not None else None
        if inc_med is None:
            st.streak = 0
            return
        best: Optional[Tuple[float, Candidate]] = None
        for c in rivals:
            res = st.reservoirs.get(cand_key(c))
            med = res.median() if res is not None else None
            if med is not None and (best is None or med < best[0]):
                best = (med, c)
        if best is None or best[0] * self.threshold >= inc_med:
            st.streak = 0                     # agreement (or no evidence)
            return
        self.stats.disagreements += 1
        st.streak += 1
        if st.streak >= self.patience:
            self._swap(st, tick, incumbent, best[1], inc_med, best[0])

    # -- swapping -------------------------------------------------------------
    def _infeasible(self, family: FamilySpec, data: Mapping[str, int],
                    cand: Candidate) -> bool:
        """Re-prove the challenger against the constraint tree — measured
        speed never overrides feasibility (same check as the disk tier's
        bucket re-validation)."""
        leaves = comprehensive_tree(family)
        if not 0 <= int(cand.leaf_index) < len(leaves):
            return True
        leaf = leaves[int(cand.leaf_index)]
        full = {**self.machine.bindings(),
                **{k: int(v) for k, v in data.items()},
                **{k: int(v) for k, v in cand.assignment.items()}}
        cs = leaf.constraints.specialize(full)
        if cs.decided:
            return cs.infeasible
        return (leaf.constraints.subs(full).check(samples=64)
                is Verdict.INCONSISTENT)

    def _swap(self, st: _TripleState, tick: int, incumbent: Candidate,
              challenger: Candidate, inc_us: float, ch_us: float) -> None:
        st.streak = 0
        if self._infeasible(st.family, st.data, challenger):
            # drop it from the pool for good: no counter sequence may ever
            # re-nominate a candidate the constraint system disproves
            self.stats.swap_blocked_infeasible += 1
            ck = cand_key(challenger)
            st.pool = [c for c in (st.pool or []) if cand_key(c) != ck]
            return
        # publish-if-unchanged: capture the generation, then merge through
        # the cache's atomic freeze path; a concurrent unfreeze/clear wins
        gen = self.cache.unfreeze_generation
        plan = self.cache.freeze_resolved(
            [(st.family, self.machine, st.data, challenger, "measured")],
            _expect_unfreeze_gen=gen)
        ent = (plan.get(st.family.name, self.machine.name, st.data)
               if plan is not None else None)
        if ent is None or cand_key(ent.candidate) != cand_key(challenger):
            self.stats.swap_blocked_gen += 1
            return
        self.stats.swaps += 1
        event = SwapEvent(tick=tick, family=st.family.name,
                          data=tuple(sorted(st.data.items())),
                          old=cand_key(incumbent), new=cand_key(challenger),
                          incumbent_us=float(inc_us),
                          challenger_us=float(ch_us),
                          windows=self.patience)
        self.events.append(event)
        if obs._recorder is not None:         # join the provenance stream
            obs._recorder.emit(event)
        _LOG.info("kernel hot-swap: %s", event.describe())

    # -- observability --------------------------------------------------------
    def stats_line(self) -> str:
        s = self.stats
        return (f"monitor probes={s.probes} windows={s.windows} "
                f"disagree={s.disagreements} swaps={s.swaps} "
                f"blocked={s.swap_blocked_infeasible + s.swap_blocked_gen}")
