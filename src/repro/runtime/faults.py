"""Seeded, deterministic fault injection + serving-side degradation tools.

The paper's output is a *case discussion*: for every ``(machine, program)``
parameter point, a ranked list of proven-feasible kernel variants — not one
winner.  :mod:`repro.runtime.ft` already treats training failures as data
(injectable ``fault_hook``, retry-from-checkpoint); this module is the
serving-side dual.  It provides the **chaos half** of the fault-tolerant
serving stack — the **degradation half** (falling down the candidate
ranking) lives in :meth:`repro.artifacts.dispatch.DispatchCache.demote` and
the engine's guarded dispatch (:mod:`repro.runtime.serving`).

Pieces:

* :class:`FaultSpec` / :class:`FaultSchedule` — a schedule of
  ``(site, tick, kind)`` faults.  ``FaultSchedule.random(seed, ...)`` draws
  a byte-reproducible schedule with site-appropriate kinds, so every chaos
  drill replays exactly and doubles as a regression test.
* :class:`FaultInjector` — the armed schedule.  Instrumented code calls
  :func:`maybe_fault`/:func:`corrupt_text` at named **injection sites**;
  when no injector is installed these are a single module-global load, so
  production pays (almost) nothing.  Firing is deterministic: a spec fires
  on a call to its site while the injector's tick equals the spec's tick
  (``tick=ANY_TICK`` fires on the next call regardless), FIFO per site,
  each spec exactly once.  The engine advances the tick
  (:func:`set_tick`); outside an engine the tick stays 0.
* Exceptions — :class:`InjectedFault` (recoverable: the degrade path must
  absorb it), :class:`InjectedIOFault` (an ``OSError``: the forgiving
  artifact readers must treat it as a cache miss), and
  :class:`FatalFault` (unrecoverable: must propagate loudly, with the
  engine left drainable).
* :class:`TickWatchdog` — hung/slow-tick detection for the serving loop,
  reusing :class:`repro.runtime.ft.StragglerMonitor`'s rolling-window
  bookkeeping (the serving engine is "host 0" watching itself).

Injection sites instrumented across the stack (kinds each site honors):

======================  =============================  ====================
site                    instrumented in                kinds
======================  =============================  ====================
``pool.alloc``          ``kv_pool.PagedKVPool.alloc``  exhaust, error, fatal
``serve.cow``           ``serving.ServeEngine``        error, fatal
``serve.prefill``       ``serving.ServeEngine``        error, fatal
``serve.decode``        ``serving.ServeEngine``        error, fatal
``serve.tick``          ``serving.ServeEngine``        slow
``artifact.read``       ``artifacts.store``            torn, garble, io
``plan.read``           ``plans.store``                torn, garble, io
``plan.apply``          ``plans.loader``               error
``monitor.probe``       ``runtime.monitor``            error
======================  =============================  ====================

This module is deliberately light (stdlib + numpy + ``runtime.ft``): the
artifact stores import it at module scope, so it must never pull jax or the
engine in.  ``repro.runtime.__init__`` is lazy for the same reason.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import recorder as obs
from ..obs.events import FaultFired
from .ft import StragglerMonitor

#: ``FaultSpec.tick`` wildcard: fire on the next call to the site, whatever
#: the injector's tick is (store/unit tests that never drive an engine).
ANY_TICK = -1

#: Fault kinds with raise semantics (handled inside :func:`maybe_fault`);
#: every other kind is *soft* — returned to the site to interpret.
RAISING_KINDS = ("error", "io", "fatal")

#: Which kinds make sense at which site (``FaultSchedule.random`` draws
#: from these; an unknown site draws "error").
SITE_KINDS: Dict[str, Tuple[str, ...]] = {
    "pool.alloc": ("exhaust",),
    "serve.cow": ("error",),
    "serve.prefill": ("error",),
    "serve.decode": ("error",),
    "serve.tick": ("slow",),
    "artifact.read": ("torn", "garble", "io"),
    "plan.read": ("torn", "garble", "io"),
    "plan.apply": ("error",),
    "monitor.probe": ("error",),
}

#: Every instrumented site (the chaos sweep iterates this).
ALL_SITES: Tuple[str, ...] = tuple(SITE_KINDS)


class FaultError(RuntimeError):
    """Base of every injected failure; carries its provenance."""

    def __init__(self, site: str, kind: str, tick: int):
        super().__init__(f"injected {kind} fault at {site} (tick {tick})")
        self.site = site
        self.kind = kind
        self.tick = tick


class InjectedFault(FaultError):
    """A *recoverable* injected failure: the graceful-degradation path
    (demote-and-retry, preemption-by-recompute, forgiving reads) must
    absorb it — an engine dying on one is the bug the drill exists to
    catch."""


class InjectedIOFault(FaultError, OSError):
    """An injected I/O failure.  Subclasses ``OSError`` so the forgiving
    artifact readers (PR 1 policy: unreadable == cache miss) swallow it on
    their existing except clauses — the drill proves the policy, it does
    not special-case it."""


class FatalFault(FaultError):
    """An *unrecoverable* injected failure: no handler may swallow it.  It
    must propagate out of the engine loudly, leaving the engine in a
    drainable state (tests call ``run_until_drained`` right after)."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``kind`` at injection-site ``site`` on a
    call made while the injector's tick equals ``tick`` (``ANY_TICK`` =
    the site's next call).  ``arg`` parameterizes the kind: byte offset
    for ``torn``/``garble``, added microseconds for ``slow``."""

    site: str
    tick: int
    kind: str = "error"
    arg: int = 0


class FaultSchedule:
    """An ordered, replayable fault list.  Equality and iteration are over
    the specs, so a schedule built from ``random(seed=k)`` is the same
    object-for-object every run — chaos drills double as regressions."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)

    @classmethod
    def random(cls, seed: int, *, sites: Sequence[str] = ALL_SITES,
               max_tick: int = 64, n: int = 4) -> "FaultSchedule":
        """Draw ``n`` faults over ``sites`` x ``[0, max_tick)`` with
        site-appropriate kinds — byte-deterministic in ``seed``."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n):
            site = sites[int(rng.integers(0, len(sites)))]
            kinds = SITE_KINDS.get(site, ("error",))
            specs.append(FaultSpec(
                site=site,
                tick=int(rng.integers(0, max_tick)),
                kind=kinds[int(rng.integers(0, len(kinds)))],
                arg=int(rng.integers(0, 4096))))
        return cls(specs)

    def __iter__(self):
        return iter(self.specs)

    def __len__(self) -> int:
        return len(self.specs)

    def __eq__(self, other) -> bool:
        return (isinstance(other, FaultSchedule)
                and self.specs == other.specs)

    def __repr__(self) -> str:
        return f"FaultSchedule({list(self.specs)!r})"


class FaultInjector:
    """An armed :class:`FaultSchedule`.

    Sites consult it through :func:`maybe_fault`/:func:`corrupt_text`; a
    spec fires when its site is called while ``self.tick`` matches (FIFO
    per site, consumed exactly once).  ``fired`` logs every fired spec in
    order — two runs of the same deterministic workload under the same
    schedule produce identical logs, which the parity tests assert."""

    def __init__(self, schedule: FaultSchedule | Sequence[FaultSpec] = ()):
        if not isinstance(schedule, FaultSchedule):
            schedule = FaultSchedule(schedule)
        self.schedule = schedule
        self._pending: Dict[str, List[FaultSpec]] = {}
        for spec in schedule:
            self._pending.setdefault(spec.site, []).append(spec)
        self.tick = 0
        self.fired: List[FaultSpec] = []

    def pending(self) -> List[FaultSpec]:
        """Specs that have not fired (scheduled ticks the workload never
        reached, or sites it never called)."""
        return [s for site in self._pending for s in self._pending[site]]

    def _pop(self, site: str) -> Optional[FaultSpec]:
        specs = self._pending.get(site)
        if not specs:
            return None
        for i, spec in enumerate(specs):
            if spec.tick == ANY_TICK or spec.tick == self.tick:
                self.fired.append(specs.pop(i))
                if obs._recorder is not None:
                    # every firing joins the provenance stream, stamped
                    # with the *injector's* tick (== the engine tick)
                    obs._recorder.emit(FaultFired(
                        tick=int(self.tick), site=spec.site,
                        kind=spec.kind, arg=int(spec.arg)))
                return spec
        return None

    def fire(self, site: str) -> Optional[FaultSpec]:
        """Pop-and-act for ``site``: raising kinds raise their exception;
        soft kinds (``exhaust``, ``slow``, ``torn``, ``garble``) are
        returned for the site to interpret; no match returns ``None``."""
        spec = self._pop(site)
        if spec is None:
            return None
        if spec.kind == "error":
            raise InjectedFault(site, spec.kind, self.tick)
        if spec.kind == "io":
            raise InjectedIOFault(site, spec.kind, self.tick)
        if spec.kind == "fatal":
            raise FatalFault(site, spec.kind, self.tick)
        return spec


# ---------------------------------------------------------------------------
# The process-wide injector (None in production: sites cost one global load)
# ---------------------------------------------------------------------------

_injector: Optional[FaultInjector] = None


def install(injector: Optional[FaultInjector]) -> None:
    global _injector
    _injector = injector


def get_injector() -> Optional[FaultInjector]:
    return _injector


def set_tick(tick: int) -> None:
    """Advance the installed injector's tick (the engine calls this at the
    top of every step; no-op when no drill is armed)."""
    if _injector is not None:
        _injector.tick = int(tick)


@contextlib.contextmanager
def inject(schedule: FaultSchedule | Sequence[FaultSpec]
           ) -> Iterator[FaultInjector]:
    """Arm a schedule for the duration of the block (tests/benchmarks/CI
    drills); always disarms on exit, even when the drill raises."""
    injector = FaultInjector(schedule)
    prev = _injector
    install(injector)
    try:
        yield injector
    finally:
        install(prev)


def maybe_fault(site: str) -> Optional[FaultSpec]:
    """The injection-site hook: one module-global load when no drill is
    armed; under a drill, fires at most one matching scheduled fault
    (raising kinds raise; soft kinds are returned for interpretation)."""
    if _injector is None:
        return None
    return _injector.fire(site)


def corrupt_text(site: str, text: str) -> str:
    """Byte-corruption hook for artifact/plan read sites.  ``torn``
    truncates at the spec's byte offset (a mid-write reader); ``garble``
    stamps a NUL over one byte (bit rot; NUL is invalid in JSON anywhere,
    so the read must parse-fail, never half-succeed); raising kinds raise.
    Without a matching spec the text passes through untouched."""
    if _injector is None:
        return text
    spec = _injector.fire(site)
    if spec is None or not text:
        return text
    off = spec.arg % max(1, len(text))
    if spec.kind == "torn":
        return text[:off]
    if spec.kind == "garble":
        return text[:off] + "\x00" + text[off + 1:]
    return text


# ---------------------------------------------------------------------------
# Tick watchdog: StragglerMonitor pointed at the serving loop itself
# ---------------------------------------------------------------------------

@dataclass
class WatchdogStats:
    ticks: int = 0                    # ticks observed
    slow_ticks: int = 0               # ticks flagged over factor x median
    last_slow_tick: int = -1          # tick index of the latest flag
    worst_ratio: float = 0.0          # max observed dt / rolling median


class TickWatchdog:
    """Flags hung/slow engine ticks against their own rolling median.

    Reuses :class:`repro.runtime.ft.StragglerMonitor`'s windowed step-time
    bookkeeping — the serving engine is recorded as host 0 and judged
    against its own history (the cross-host comparison ``stragglers()``
    does is meaningless with one host, so the flagging math lives here).
    A tick is *slow* when its duration exceeds ``factor`` x the rolling
    median of the last ``window`` ticks, once ``min_samples`` ticks have
    been seen; detection is pure and unit-tested with fabricated
    durations."""

    def __init__(self, *, factor: float = 4.0, window: int = 64,
                 min_samples: int = 8):
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1.0: {factor}")
        self.monitor = StragglerMonitor(factor=factor, window=window,
                                        min_samples=min_samples)
        self.stats = WatchdogStats()

    def observe(self, seconds: float, tick: Optional[int] = None) -> bool:
        """Record one tick duration; returns True when it flags as slow
        (judged against the history *before* this tick, so one hung tick
        cannot hide itself by dragging the median up)."""
        st = self.stats
        buf = self.monitor._times.get(0, [])
        flagged = False
        if len(buf) >= self.monitor.min_samples:
            med = float(np.median(buf))
            if med > 0.0:
                ratio = float(seconds) / med
                st.worst_ratio = max(st.worst_ratio, ratio)
                flagged = ratio > self.monitor.factor
        self.monitor.record(0, float(seconds))
        if flagged:
            st.slow_ticks += 1
            st.last_slow_tick = tick if tick is not None else st.ticks
        st.ticks += 1
        return flagged

    def stats_line(self) -> str:
        st = self.stats
        return (f"watchdog ticks={st.ticks} slow={st.slow_ticks} "
                f"worst={st.worst_ratio:.1f}x")


# ---------------------------------------------------------------------------
# Injectable clocks (deadline/TTL plumbing shares them)
# ---------------------------------------------------------------------------

#: Default wall clock for deadlines and the watchdog; tests inject fakes.
Clock = Callable[[], float]
default_clock: Clock = time.monotonic
